"""Test-support utilities.

``install_hypothesis_fallback`` registers a minimal, deterministic
stand-in for the ``hypothesis`` package when the real one is not
installed (hermetic CI images), so property tests still collect and run.
The fallback draws a fixed number of examples per test — the strategy
bounds first, then seeded-random interior points — which keeps the
property tests meaningful (boundaries are where quantization code
breaks) and perfectly reproducible. The ``HYPOTHESIS_SEED`` env var
(default ``0``) seeds the interior draws; CI runs the statistical suite
under a small seed matrix so a pass never hinges on one lucky stream.
With real hypothesis installed this module does nothing.

Only the API surface the repo's tests use is implemented: ``given``,
``settings``, ``assume``, ``HealthCheck``, and the ``integers`` /
``floats`` / ``booleans`` / ``sampled_from`` / ``just`` strategies.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types


class _Example(Exception):
    """Raised by assume() to skip one drawn example."""


class _Strategy:
    """Generates n deterministic examples: bounds first, then random."""

    def __init__(self, gen):
        self._gen = gen

    def examples(self, rng: random.Random, n: int) -> list:
        return self._gen(rng, n)


def _bounded(bounds, draw):
    def gen(rng, n):
        vals = list(bounds)[:n]
        while len(vals) < n:
            vals.append(draw(rng))
        return vals
    return _Strategy(gen)


def install_hypothesis_fallback() -> bool:
    """Install the shim into sys.modules; returns True if installed,
    False if real hypothesis is available (then nothing happens)."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _bounded((min_value, max_value),
                        lambda rng: rng.randint(min_value, max_value))

    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _bounded((min_value, max_value),
                        lambda rng: rng.uniform(min_value, max_value))

    def booleans() -> _Strategy:
        return _bounded((False, True), lambda rng: rng.random() < 0.5)

    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _bounded((), lambda rng: seq[rng.randrange(len(seq))])

    def just(value) -> _Strategy:
        return _bounded((value,), lambda rng: value)

    def settings(**kw):
        def deco(fn):
            fn._hyp_settings = dict(kw)
            return fn
        return deco

    def assume(condition):
        if not condition:
            raise _Example()
        return True

    def given(*strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = params[:len(params) - len(strategies)]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_hyp_settings", {})
                n = int(cfg.get("max_examples", 20))
                rng = random.Random(
                    int(os.environ.get("HYPOTHESIS_SEED", "0")))
                cols = [s.examples(rng, n) for s in strategies]
                for drawn in zip(*cols):
                    try:
                        fn(*args, *drawn, **kwargs)
                    except _Example:
                        continue

            # hide strategy params so pytest doesn't look for fixtures
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.__version__ = "0.0.0+repro-fallback"
    strat = types.ModuleType("hypothesis.strategies")
    for name, obj in (("integers", integers), ("floats", floats),
                      ("booleans", booleans), ("sampled_from", sampled_from),
                      ("just", just)):
        setattr(strat, name, obj)
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large",
        filter_too_much="filter_too_much")
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
    return True
