"""Distribution layer: logical-axis sharding rules + jax compat shims."""

from repro.dist import compat as _compat

_compat.install()  # before anything reads jax.sharding.*

from repro.dist.sharding import (  # noqa: E402
    DEFAULT_RULES,
    MULTIPOD_RULES,
    MULTIPOD_SERVE_RULES,
    SERVE_RULES,
    axis_rules,
    current_rules,
    fit_spec_to_shape,
    sanitize_shardings,
    shard,
    spec_for,
)

__all__ = [
    "DEFAULT_RULES",
    "MULTIPOD_RULES",
    "MULTIPOD_SERVE_RULES",
    "SERVE_RULES",
    "axis_rules",
    "current_rules",
    "fit_spec_to_shape",
    "sanitize_shardings",
    "shard",
    "spec_for",
]
