"""Feature-gated shims for the jax public sharding API.

The repo targets the modern (jax >= 0.5) surface — two-argument
``jax.sharding.AbstractMesh``, ``jax.sharding.AxisType``, and
``jax.make_mesh(..., axis_types=...)`` — but must also run on the pinned
0.4.x toolchain, where ``AbstractMesh`` takes a single ``((name, size),
...)`` tuple and axis types do not exist yet. ``install()`` patches the
*missing* pieces into the running jax, and only those: on a jax that
already provides the modern API every installer is a no-op, so nothing
is ever downgraded or double-wrapped.

Installed from ``repro.dist.__init__`` — importing any model / train /
launch module therefore guarantees the shims are active.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install_abstract_mesh() -> None:
    orig = jax.sharding.AbstractMesh
    try:
        orig((1,), ("_probe",))
        return  # modern signature already supported
    except TypeError:
        pass

    # patch __init__ in place (rather than wrapping the class) so the
    # class object — and with it isinstance checks, subclasses, and
    # jax-internal constructions — stays identical
    orig_init = orig.__init__

    @functools.wraps(orig_init)
    def __init__(self, axis_sizes, axis_names=None, **kwargs):
        if axis_names is None:  # legacy ((name, size), ...) form
            orig_init(self, axis_sizes, **kwargs)
        else:
            orig_init(self, tuple(zip(axis_names, axis_sizes)))

    orig.__init__ = __init__


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    orig = jax.make_mesh
    if "axis_types" in inspect.signature(orig).parameters:
        return

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        # pre-AxisType jax has exactly one behaviour (Auto, i.e. GSPMD
        # propagation with sharding constraints), so the kwarg is dropped
        del axis_types
        return orig(axis_shapes, axis_names, **kwargs)

    jax.make_mesh = make_mesh


def _install_cost_analysis() -> None:
    comp = jax.stages.Compiled
    orig = comp.cost_analysis
    if getattr(orig, "_repro_compat", False):
        return

    @functools.wraps(orig)
    def cost_analysis(self):
        # jax < 0.5 returns a one-element list of per-program dicts;
        # modern jax returns the dict directly
        out = orig(self)
        if isinstance(out, list) and len(out) == 1:
            return out[0]
        return out

    cost_analysis._repro_compat = True
    comp.cost_analysis = cost_analysis


def install() -> None:
    """Idempotently install every missing shim."""
    _install_abstract_mesh()
    _install_axis_type()
    _install_make_mesh()
    _install_cost_analysis()
