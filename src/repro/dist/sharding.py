"""Logical-axis sharding rules (GShard / t5x style).

Model code annotates arrays with *logical* axis names ("batch", "fsdp",
"tp", ...). A rule set maps each logical axis to zero or more *physical*
mesh axes; ``spec_for`` resolves a tuple of logical axes against the
active rules and a mesh into a ``PartitionSpec``, dropping any mesh axis
that is already used by an earlier dimension (a mesh axis can shard at
most one dimension of an array — duplicates degrade to replication, they
are never an error). The same annotation therefore lowers correctly on a
train mesh, a serve mesh, or no mesh at all.

Rule sets are ordered ``(logical_axis, physical_axes)`` pairs; first
match wins, so a more specific rule set can be built by prepending
overrides to an existing one. Physical axes may be ``None`` (always
replicate), one mesh-axis name, or a tuple of names (shard over their
product, e.g. serve-mode tensor parallelism over the whole pod).

Entry points pick their rule set in ``repro.launch.dryrun`` /
``repro.launch.serve``: DEFAULT_RULES for train/prefill on one pod,
SERVE_RULES for decode (weights stationary over the whole mesh, batch
sharding carried by the KV cache's ``cache_batch``), and the MULTIPOD_*
variants which add the "pod" axis for cross-pod data parallelism.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

# one logical axis -> physical mesh axes (None | str | tuple of str)
Rules = tuple[tuple[str, None | str | tuple[str, ...]], ...]

# Train / prefill, single pod ("data", "model"): FSDP over data, tensor
# parallelism (and sequence parallelism for activations) over model.
DEFAULT_RULES: Rules = (
    ("batch", "data"),
    ("cache_batch", "data"),
    ("fsdp", "data"),
    ("seq", "model"),
    ("tp", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("experts", "model"),
    ("vocab", "model"),
)

# Decode, single pod: weights are stationary and shard their output dims
# over the *whole* mesh (no fsdp — no gathers on the latency path); the
# per-request batch rides on the KV cache's cache_batch axis while the
# activation "batch" annotation replicates.
SERVE_RULES: Rules = (
    ("batch", None),
    ("cache_batch", "data"),
    ("fsdp", None),
    ("seq", "model"),
    ("tp", ("data", "model")),
    ("heads", ("data", "model")),
    ("kv_heads", ("data", "model")),
    ("experts", ("data", "model")),
    ("vocab", ("data", "model")),
)

# Train / prefill across pods ("pod", "data", "model"): pure data
# parallelism over the pod axis (gradients all-reduce across pods once
# per step), FSDP kept intra-pod where the links are fast.
MULTIPOD_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("cache_batch", ("pod", "data")),
    ("fsdp", "data"),
    ("seq", "model"),
    ("tp", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("experts", "model"),
    ("vocab", "model"),
)

# Decode across pods: each pod holds a full weight replica (tp spans one
# pod's mesh), requests split across pods via cache_batch.
MULTIPOD_SERVE_RULES: Rules = (
    ("batch", None),
    ("cache_batch", ("pod", "data")),
    ("fsdp", None),
    ("seq", "model"),
    ("tp", ("data", "model")),
    ("heads", ("data", "model")),
    ("kv_heads", ("data", "model")),
    ("experts", ("data", "model")),
    ("vocab", ("data", "model")),
)


class _RulesContext(threading.local):
    def __init__(self):
        self.stack: list[Rules] = []


_ctx = _RulesContext()


@contextlib.contextmanager
def axis_rules(rules: Rules):
    """Bind a rule set for the dynamic extent of the context (re-entrant;
    the innermost binding wins)."""
    _ctx.stack.append(tuple(rules))
    try:
        yield
    finally:
        _ctx.stack.pop()


def current_rules() -> Rules:
    """The innermost bound rule set, or DEFAULT_RULES outside any
    ``axis_rules`` context."""
    return _ctx.stack[-1] if _ctx.stack else DEFAULT_RULES


def _lookup(rules: Rules, logical: str):
    for name, phys in rules:
        if name == logical:
            return phys
    return None


def _mesh_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def spec_for(logical_axes: Sequence[str | None], mesh,
             rules: Rules | None = None) -> PartitionSpec:
    """Resolve logical axes to a ``PartitionSpec`` for ``mesh``.

    Unknown logical axes, mesh axes the mesh doesn't have, and mesh axes
    already claimed by an earlier dimension all resolve to replication.
    Trailing replicated dims are trimmed (``P("x", None)`` -> ``P("x")``)
    so specs compare cleanly.
    """
    rules = current_rules() if rules is None else rules
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries: list[None | str | tuple[str, ...]] = []
    for ax in logical_axes:
        if ax is None:
            entries.append(None)
            continue
        phys = _lookup(rules, ax)
        if phys is None:
            entries.append(None)
            continue
        tup = (phys,) if isinstance(phys, str) else tuple(phys)
        tup = tuple(p for p in tup if p in sizes)
        if not tup or any(p in used for p in tup):
            entries.append(None)
            continue
        used.update(tup)
        entries.append(tup[0] if len(tup) == 1 else tup)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def fit_spec_to_shape(spec: PartitionSpec, shape: Sequence[int],
                      mesh) -> PartitionSpec:
    """Drop sharded axes that do not evenly divide their dimension.

    For a tuple entry, trailing sub-axes are peeled off until the product
    of the remaining axis sizes divides the dim (a prefix of a product
    sharding is still a valid sharding); a fully peeled entry replicates.
    """
    entries = tuple(spec)
    if len(entries) > len(shape):
        if any(e is not None for e in entries[len(shape):]):
            raise ValueError(
                f"spec {spec} has rank {len(entries)} but shape {tuple(shape)} "
                f"has rank {len(shape)}")
        entries = entries[:len(shape)]
    sizes = _mesh_sizes(mesh)
    out: list[None | str | tuple[str, ...]] = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        tup = (e,) if isinstance(e, str) else tuple(e)
        while tup:
            n = 1
            for p in tup:
                n *= sizes[p]
            if dim % n == 0:
                break
            tup = tup[:-1]
        if not tup:
            out.append(None)
        else:
            out.append(tup[0] if len(tup) == 1 else tup)
    return PartitionSpec(*out)


def sanitize_shardings(shardings, abstract_tree):
    """Validate a sharding pytree against the matching abstract-eval tree.

    Every ``NamedSharding`` leaf is re-fit to its array's concrete shape
    (indivisible axes degrade to replication instead of failing at
    compile time); a spec whose rank exceeds the array's, or a tree whose
    structure does not match ``abstract_tree``, raises ``ValueError``.
    """

    def _fix(sh, ab):
        if not isinstance(sh, NamedSharding):
            return sh
        return NamedSharding(sh.mesh,
                             fit_spec_to_shape(sh.spec, tuple(ab.shape),
                                               sh.mesh))

    try:
        return jax.tree.map(_fix, shardings, abstract_tree)
    except ValueError as e:
        raise ValueError(f"sharding pytree does not match abstract tree: {e}") \
            from e


_warned_no_mesh_api = False


def _active_mesh():
    """The physical mesh bound by ``with mesh:``, or None."""
    global _warned_no_mesh_api
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except (ImportError, AttributeError):
        # private API moved: warn once instead of silently disabling every
        # sharding constraint (which would only show up as lost throughput)
        if not _warned_no_mesh_api:
            _warned_no_mesh_api = True
            import warnings
            warnings.warn(
                "repro.dist: cannot read the active mesh from this jax "
                "version (jax._src.mesh.thread_resources missing); shard() "
                "constraints are DISABLED", RuntimeWarning, stacklevel=2)
    return None


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names.

    A graceful no-op when no mesh is active (pure-CPU tests, eager use)
    or when the bound rule set is empty, so model code is annotated
    unconditionally and only pays for it under ``with mesh:``. The rank
    check runs even in no-op mode so annotation bugs surface in CPU
    tests rather than on the first production mesh.
    """
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard() got {len(logical_axes)} logical axes "
            f"{logical_axes} for an array of rank {x.ndim}")
    mesh = _active_mesh()
    if mesh is None:
        return x
    rules = current_rules()
    if not rules:
        return x
    spec = spec_for(logical_axes, mesh, rules)
    spec = fit_spec_to_shape(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
