"""Deterministic synthetic data pipelines (offline container, no datasets).

SyntheticLM: a Markov-chain token stream with enough structure that a
small LM's loss falls well below the uniform entropy — used for the e2e
training example and the convergence tests. Deterministic per (seed, step),
sharded per host by taking every ``num_hosts``-th batch, and resumable from
any step offset (the fault-tolerance contract).

TeacherDataset: inputs labeled by a frozen random teacher MLP — used by the
Table-4 accuracy reproduction (train a student, then compare float vs
RAELLA-simulated inference).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    order: int = 1          # Markov order
    concentration: float = 0.3  # lower -> more predictable

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse-ish row-stochastic transition matrix
        self._trans = rng.dirichlet(
            np.full(v, self.concentration), size=v).astype(np.float32)
        self._cum = np.cumsum(self._trans, axis=1)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S, v = self.batch_size, self.seq_len, self.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, v, B)
        u = rng.random((B, S))
        for t in range(1, S):
            rows = self._cum[toks[:, t - 1]]
            toks[:, t] = (u[:, t:t + 1] < rows).argmax(axis=1)
        return {"inputs": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    def iterator(self, start_step: int = 0, *, host: int = 0,
                 num_hosts: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step * num_hosts + host)
            step += 1

    def entropy_floor(self) -> float:
        """Mean conditional entropy of the chain (nats) — the loss floor."""
        p = self._trans
        h = -(p * np.log(np.maximum(p, 1e-12))).sum(axis=1)
        return float(h.mean())


@dataclasses.dataclass
class TeacherDataset:
    """Classification set labeled by a frozen random teacher network."""
    d_in: int
    n_classes: int
    seed: int = 0
    hidden: int = 64

    def __post_init__(self):
        k1, k2, k3 = jax.random.split(jax.random.key(self.seed), 3)
        self.w1 = jax.random.normal(k1, (self.d_in, self.hidden)) * self.d_in ** -0.5
        self.w2 = jax.random.normal(k2, (self.hidden, self.n_classes)) * self.hidden ** -0.5

    def batch(self, step: int, batch_size: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.key(self.seed + 1), step)
        x = jax.random.normal(key, (batch_size, self.d_in))
        logits = jnp.maximum(x @ self.w1, 0.0) @ self.w2
        return x, jnp.argmax(logits, axis=-1)


def batch_iterator(source: SyntheticLM, start_step: int = 0) -> Iterator[dict]:
    return source.iterator(start_step)
