from repro.data.pipeline import (
    SyntheticLM,
    TeacherDataset,
    batch_iterator,
)

__all__ = ["SyntheticLM", "TeacherDataset", "batch_iterator"]
