"""Gradient compression for the cross-pod (DCN) axis: int8 + error feedback.

At 2+ pods the gradient all-reduce crosses the data-center network — the
slowest link in the system. We compress gradients to int8 with per-chunk
scales before the cross-pod reduction and keep the quantization residual in
an *error-feedback* buffer added to the next step's gradient, which is the
standard convergence-preserving trick (1-bit Adam / EF21 family).

``compressed_psum`` is built on shard_map so the quantize -> psum ->
dequantize pipeline is explicit in the HLO (the int8 tensor is what crosses
the DCN). Used by the train loop when cfg has pod-DP and
``grad_compression='int8_ef'``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

CHUNK = 1024  # scale granularity (per-chunk absmax)


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape) -> (int8 codes, per-chunk fp32 scales)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, CHUNK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(x: jnp.ndarray,
                        err: jnp.ndarray | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One EF round locally: returns (decompressed, new_error).

    decompressed = Q(x + err); new_error = (x + err) - decompressed.
    """
    target = x if err is None else x + err.astype(x.dtype)
    q, s = quantize_int8(target)
    deq = dequantize_int8(q, s, x.shape, x.dtype)
    return deq, (target.astype(jnp.float32)
                 - deq.astype(jnp.float32)).astype(x.dtype)


def compressed_psum(tree: Any, mesh: Mesh, axis: str,
                    err_tree: Any | None = None) -> tuple[Any, Any]:
    """Mean-reduce a pytree across ``axis`` with int8+EF compression.

    Each leaf is quantized (with its error-feedback carry), the int8 codes
    and scales are what cross the axis, and the dequantized mean is
    returned along with the updated error buffers.
    """
    if err_tree is None:
        err_tree = jax.tree.map(lambda g: jnp.zeros_like(g), tree)
    n = mesh.shape[axis]

    def one(g, e):
        rest = tuple(a for a in mesh.axis_names if a != axis)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(*[None] * g.ndim), P(*[None] * g.ndim)),
            out_specs=(P(*[None] * g.ndim), P(*[None] * g.ndim)),
            check_rep=False)
        def body(gl, el):
            target = gl + el.astype(gl.dtype)
            q, s = quantize_int8(target)
            q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
            s_mean = jax.lax.pmean(s, axis)  # shared scale approximation
            deq = dequantize_int8((q_sum / n), s_mean, gl.shape, gl.dtype)
            new_e = (target.astype(jnp.float32)
                     - dequantize_int8(q, s, gl.shape, gl.dtype)
                     .astype(jnp.float32)).astype(gl.dtype)
            return deq, new_e
        return body(g, e)

    out = jax.tree.map(one, tree, err_tree)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err
