"""AdamW + LR schedules, pure JAX (no optax on the image).

Optimizer moments can be kept in bf16 (``state_dtype='bfloat16'``) for
>=100B-parameter configs — with stochastic-rounding-free bf16 m/v the HBM
cost per parameter drops from 2+4+4+4 to 2+2+2+2 bytes, which is what lets
the 400B-class models train on a single 256-chip v5e pod (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | constant


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * frac))


def init_state(cfg: AdamWConfig, params: Any) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), gn


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def state_specs(param_specs: Any) -> dict:
    """Optimizer-state logical shardings mirror the parameter shardings."""
    return {"m": param_specs, "v": param_specs, "step": ()}
