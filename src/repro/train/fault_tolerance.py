"""Fault tolerance for 1000+-node runs: restart, elasticity, stragglers.

Three mechanisms, all exercised by tests:

1. **Checkpoint/restart** — ``resilient_train`` wraps the train loop:
   periodic (async) checkpoints, automatic restore-on-start, and a bounded
   retry loop around step execution so a transient failure resumes from the
   last checkpoint instead of killing the job.

2. **Elastic re-meshing** — checkpoints are mesh-shape-agnostic (host
   arrays + logical shardings), so ``restore`` can re-place state onto a
   different device count after node loss; ``elastic_data_axis`` picks the
   largest usable data-parallel degree for the surviving devices.

3. **Straggler detection** — ``StragglerMonitor`` keeps a robust running
   estimate of step time (median + MAD) and flags steps exceeding a
   threshold multiple; the launcher's response at scale is documented in
   DESIGN.md (re-schedule the slow host's shards / drop to the elastic
   path). On one host we surface the signal and count events.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Iterator

import jax

from repro.configs.base import ArchConfig
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train import train_loop as tl


# ------------------------------------------------------------- stragglers
@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the running median."""
    threshold: float = 3.0
    window: int = 50
    min_samples: int = 5
    times: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                is_straggler = True
                self.events.append((step, dt, med))
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        return is_straggler

    def hook(self):
        def _h(state, metrics, dt):
            if self.observe(state.step, dt):
                print(f"[straggler] step {state.step}: {dt*1e3:.0f} ms "
                      f"(median {statistics.median(self.times)*1e3:.0f} ms)")
        return _h


# ------------------------------------------------------------- elasticity
def elastic_data_axis(n_devices: int, model_parallel: int) -> int:
    """Largest data-parallel degree for the surviving device count."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot hold model-parallel degree "
            f"{model_parallel}")
    return n_devices // model_parallel


# ------------------------------------------------------------- restart loop
def resilient_train(cfg: ArchConfig,
                    opt_cfg: opt.AdamWConfig,
                    data_fn: Callable[[int], Iterator[dict]],
                    *,
                    num_steps: int,
                    ckpt_dir: str,
                    ckpt_every: int = 50,
                    max_restarts: int = 3,
                    monitor: StragglerMonitor | None = None,
                    fail_injector: Callable[[int], None] | None = None
                    ) -> tl.TrainState:
    """Train with periodic async checkpoints and restore-on-failure.

    ``data_fn(start_step)`` rebuilds the (deterministic) data stream from a
    step offset so restarts do not replay or skip batches.
    ``fail_injector(step)`` lets tests raise mid-run to exercise recovery.
    """
    from repro.models import transformer as T

    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    monitor = monitor or StragglerMonitor()
    restarts = 0

    while True:
        # ---- (re)build state: restore if a checkpoint exists
        params, _ = T.init_params(cfg, jax.random.key(0))
        opt_state = opt.init_state(opt_cfg, params)
        state = tl.TrainState(params, opt_state, 0)
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            (state.params, state.opt_state), step, _ = ckpt.restore(
                ckpt_dir, (state.params, state.opt_state))
            state.step = step
            print(f"[restart] resumed from step {step}")
        step_fn = jax.jit(tl.make_train_step(cfg, opt_cfg),
                          donate_argnums=(0, 1))
        data_iter = data_fn(state.step)

        def hook(st, metrics, dt):
            monitor.hook()(st, metrics, dt)
            if st.step % ckpt_every == 0:
                saver.save(st.step, (st.params, st.opt_state))

        try:
            while state.step < num_steps:
                batch = next(data_iter)
                if fail_injector is not None:
                    fail_injector(state.step)
                t0 = time.monotonic()
                state.params, state.opt_state, metrics = step_fn(
                    state.params, state.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                state.step += 1
                hook(state, metrics, time.monotonic() - t0)
            saver.wait()
            saver.save(state.step, (state.params, state.opt_state))
            saver.wait()
            return state
        except (RuntimeError, ValueError, OSError) as e:
            restarts += 1
            print(f"[failure] step {state.step}: {e!r} "
                  f"(restart {restarts}/{max_restarts})")
            saver.wait()
            if restarts > max_restarts:
                raise
