# Submodules imported lazily (checkpoint/compression/fault_tolerance pull in
# threading/IO machinery that dryrun does not need).
