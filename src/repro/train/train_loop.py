"""Training step + loop: microbatched gradient accumulation, AdamW, logging.

``make_train_step`` is the single source of truth for the train step — the
multi-pod dry-run lowers exactly what the real launcher runs.

Microbatching (``cfg.micro_batches``): the global batch (fixed by the
assigned input shape) is processed as a lax.scan over micro-batches with
gradient accumulation, dividing activation memory by the micro count —
how 400B-class models fit 1M-token steps on a 256-chip pod. Gradients
accumulate in the optimizer-state dtype (bf16 for the >=100B configs).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.train import optimizer as opt


def make_train_step(cfg: ArchConfig, opt_cfg: opt.AdamWConfig,
                    loss_fn: Callable | None = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = loss_fn or (lambda p, b: T.lm_loss(p, cfg, b))
    n_micro = max(1, cfg.micro_batches)
    acc_dt = jnp.dtype(cfg.opt_state_dtype)
    pspecs = T.param_specs(cfg)

    def grads_of(params, batch):
        loss, g = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
        # pin each microbatch gradient to the parameter sharding so the
        # cross-data reduction lowers as reduce-scatter (half the link bytes
        # of an all-reduce) straight into the FSDP shard
        from repro.dist import shard as _shard
        g = jax.tree.map(
            lambda a, ax: _shard(a, *ax), g, pspecs,
            is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
        return loss, g

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = grads_of(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gacc, g)
                return (gacc, lacc + l), None

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = jax.lax.scan(body, (gz, jnp.zeros((), jnp.float32)),
                                           micro)
            grads = jax.tree.map(lambda g: (g / n_micro).astype(acc_dt), gsum)
            loss = lsum / n_micro
        new_p, new_o, metrics = opt.apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        return new_p, new_o, dict(metrics, loss=loss)

    return train_step


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def train(cfg: ArchConfig,
          opt_cfg: opt.AdamWConfig,
          data_iter: Iterator[dict],
          *,
          num_steps: int,
          state: TrainState | None = None,
          jitted_step: Callable | None = None,
          hooks: list[Callable] | None = None,
          log_every: int = 10) -> TrainState:
    """Simple synchronous training loop (single-host driver).

    ``hooks`` are called as hook(state, metrics, step_time) after each step —
    checkpointing, straggler monitoring and eval plug in here.
    """
    if state is None:
        params, _ = T.init_params(cfg, jax.random.key(0))
        opt_state = opt.init_state(opt_cfg, params)
        state = TrainState(params, opt_state, 0)
    step_fn = jitted_step or jax.jit(make_train_step(cfg, opt_cfg),
                                     donate_argnums=(0, 1))
    hooks = hooks or []
    for _ in range(num_steps):
        batch = next(data_iter)
        t0 = time.monotonic()
        state.params, state.opt_state, metrics = step_fn(
            state.params, state.opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        state.step += 1
        for h in hooks:
            h(state, metrics, dt)
        if log_every and state.step % log_every == 0:
            print(f"step {state.step}: loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)")
    return state
