"""Fault-tolerant checkpointing: atomic, async, mesh-shape-agnostic.

Layout (one directory per step):
    <dir>/step_000123.tmp/...   (written)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           tree structure, shapes, dtypes, step
        arr_000000.npy ...      one file per leaf (host-gathered)

Restore is *elastic*: arrays are loaded host-side and re-placed with
whatever shardings the (possibly different) mesh dictates, so a job can
come back on a different pod count after failures. Saves can run on a
background thread (async=True) so the step loop never blocks on IO.

On a multi-host deployment each host would write only its addressable
shards (same manifest, per-host files); this single-host implementation
writes full arrays — the format and atomicity protocol are identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(directory: str, step: int, tree: Any, *,
         extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = jax.tree.flatten(tree)
    meta = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "n_leaves": len(flat),
        "leaves": [],
        "extra": extra or {},
        "time": time.time(),
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8, ...)
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32}[arr.dtype.itemsize])
        np.save(os.path.join(tmp, f"arr_{i:06d}.npy"), arr)
        meta["leaves"].append({"shape": list(arr.shape), "dtype": logical})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _gc(directory, keep=3)
    return final


class AsyncCheckpointer:
    """Background-thread checkpoint writer; at most one save in flight.

    ``wait()`` before exiting. A crash mid-save leaves only a .tmp dir,
    which restore ignores — the previous complete checkpoint wins.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation
        self._thread = threading.Thread(
            target=save, args=(self.directory, step, host_tree),
            kwargs={"extra": extra}, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, _MANIFEST))]
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings`` (optional pytree of NamedShardings) re-places every leaf
    for the *current* mesh — elastic restart after topology changes.
    Returns (tree, step, extra).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        meta = json.load(f)
    flat_like, treedef = jax.tree.flatten(tree_like)
    if meta["n_leaves"] != len(flat_like):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, expected "
            f"{len(flat_like)} — structure changed")
    import ml_dtypes
    leaves = []
    for i, like in enumerate(flat_like):
        arr = np.load(os.path.join(path, f"arr_{i:06d}.npy"))
        logical = meta["leaves"][i]["dtype"]
        if str(arr.dtype) != logical:
            arr = arr.view(np.dtype(getattr(ml_dtypes, logical, logical)))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {like.shape}")
        leaves.append(arr)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(
            lambda a, l: jnp.asarray(a, dtype=l.dtype), tree, tree_like)
    return tree, step, meta.get("extra", {})


def _gc(directory: str, keep: int) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
