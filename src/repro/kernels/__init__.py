# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Kernels here: int8_matmul (Eq. 1 fast path), sliced_crossbar
# (slice-pair contraction), fused_crossbar (the whole exact datapath:
# in-kernel input slicing + per-segment ADC + shift-and-accumulate +
# center term + saturation counting). ``ops`` fronts them with the
# kernel-backend registry (xla / interpret / pallas-tpu, env override
# REPRO_KERNEL_BACKEND); ``ref`` holds the pure-jnp oracles.
