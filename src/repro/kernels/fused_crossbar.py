"""Pallas TPU kernel: the *whole* RAELLA exact datapath in one launch.

``sliced_crossbar.py`` fuses the (input-slice x weight-slice) contraction
but still expects pre-sliced inputs, leaves the digital center term to a
separate einsum, and throws the saturation counts away. This kernel goes
the rest of the way: one ``pallas_call`` performs

  1. temporal input slicing — the 8b input block is loaded once per
     (batch, segment) and the i-th slice is cropped *in-kernel* with a
     shift+mask (no (n_i, B, R) slice tensor ever materializes in HBM);
  2. the slice-plane matmul per 512-row crossbar segment (int8 MXU dots
     whenever every input-slice width is < 8);
  3. the per-segment signed ADC: an integer clamp to [adc_lo, adc_hi] —
     bit-identical to ``core.adc.convert`` at noise 0, because in-range
     column sums are < 2^24 so the float32 round there is exact;
  4. the digital shift+accumulate via a per-(i, j) multiplier table
     ``mults[i, j] = valid_j << (l_i + l_j)`` — ragged per-site plans
     (``slice_shifts`` / ``slice_valid`` from ``models.pim_compile``)
     just zero the padding multipliers, and zero planes clamp to 0, so
     the padding contract holds inside the kernel too;
  5. the digital center term ``phi * sum(x)``, accumulated once per
     segment from the already-resident input block;
  6. ADC saturation counting (clamp hit either bound), masked to the
     true (B, C) extent so tile padding never inflates the counters.

Everything downstream (``core.crossbar.forward`` stats, ``core.energy``,
``CompiledPim.report``) keys off the outputs, so the kernel returns both
the psum block and the scalar saturation count.

Grid: (B/bm, C/bn, n_seg, n_i, n_j) — output revisited across the last
three axes, accumulating in a VMEM scratch (per-chunk carries; column
sums never round-trip to HBM). The input block's index map ignores
(c, i, j), so Pallas keeps it resident while all slices are cropped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS_PER_XBAR = 512
DEFAULT_BM = 128
DEFAULT_BN = 256


def _kernel(x_ref, w_ref, li_ref, mask_ref, mult_ref, cen_ref,
            o_ref, sat_ref, acc_ref, *,
            n_seg: int, n_i: int, n_j: int, adc_lo: int, adc_hi: int,
            bm: int, bn: int, b_true: int, c_true: int, narrow: bool):
    b = pl.program_id(0)
    c = pl.program_id(1)
    s = pl.program_id(2)
    i = pl.program_id(3)
    j = pl.program_id(4)
    first = (s == 0) & (i == 0) & (j == 0)

    @pl.when(first)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(first & (b == 0) & (c == 0))
    def _init_sat():
        sat_ref[0, 0] = jnp.zeros((), jnp.int32)

    x = x_ref[...]  # (bm, rows_per_xbar) int32, unsigned 8b codes

    # digital center term: phi * sum_r(x), once per (b, c, s)
    @pl.when((i == 0) & (j == 0))
    def _center():
        acc_ref[...] += x.sum(axis=1, keepdims=True) * cen_ref[0]

    # temporal input slicing, in-register: (x >> l_i) & ((1 << w_i) - 1)
    x_i = jax.lax.shift_right_logical(x, li_ref[0, 0]) & mask_ref[0, 0]
    if narrow:  # every slice value < 128 -> int8 x int8 MXU dot
        cs = jax.lax.dot(x_i.astype(jnp.int8), w_ref[0],
                         preferred_element_type=jnp.int32)
    else:
        cs = jax.lax.dot(x_i, w_ref[0].astype(jnp.int32),
                         preferred_element_type=jnp.int32)
    cs = jnp.clip(cs, adc_lo, adc_hi)  # the per-segment signed ADC

    # saturation counter, masked to the true (B, C) extent
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + b * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + c * bn
    in_bounds = (rows < b_true) & (cols < c_true)
    sat = ((cs == adc_lo) | (cs == adc_hi)) & in_bounds
    sat_ref[0, 0] += sat.astype(jnp.int32).sum()

    acc_ref[...] += cs * mult_ref[0, 0]  # digital shift+add

    last = (s == n_seg - 1) & (i == n_i - 1) & (j == n_j - 1)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "adc_lo", "adc_hi", "bm", "bn", "rows_per_xbar", "narrow", "interpret"))
def fused_crossbar(x_u8: jnp.ndarray, w_planes: jnp.ndarray,
                   in_li: jnp.ndarray, in_mask: jnp.ndarray,
                   mults: jnp.ndarray, centers: jnp.ndarray, *,
                   adc_lo: int = -64, adc_hi: int = 63,
                   bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   rows_per_xbar: int = ROWS_PER_XBAR,
                   narrow: bool = True,
                   interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused exact-datapath forward.

    x_u8:     (B, R) int32 — unsigned 8b input codes (R = true rows).
    w_planes: (n_j, Rp, C) int8 — signed slice planes, Rp a multiple of
              ``rows_per_xbar`` >= R (zero row padding is exact).
    in_li:    (n_i,) int32 — per input slice, the low bit index l_i.
    in_mask:  (n_i,) int32 — per input slice, (1 << width_i) - 1.
    mults:    (n_i, n_j) int32 — recombination multipliers; 0 kills a
              padded weight slice entirely.
    centers:  (n_seg, C) int32 — per-segment Center+Offset phi.
    narrow:   every input-slice width < 8 (values fit int8) — lets the
              slice dots run int8 x int8 on the MXU.

    Returns (psum (B, C) int32 including the center term,
             saturations () int32 — ADC clamps that hit either bound).
    """
    B, R = x_u8.shape
    n_j, Rp, C = w_planes.shape
    assert Rp % rows_per_xbar == 0 and Rp >= R, (Rp, R)
    n_seg = Rp // rows_per_xbar
    n_i = in_li.shape[0]
    bm = min(bm, _rup(B, 8))
    bn = min(bn, _rup(C, 128))
    Bp, Cp = _rup(B, bm), _rup(C, bn)
    x_p = jnp.pad(x_u8.astype(jnp.int32), ((0, Bp - B), (0, Rp - R)))
    w_p = jnp.pad(w_planes, ((0, 0), (0, 0), (0, Cp - C)))
    cen_p = jnp.pad(centers.astype(jnp.int32), ((0, 0), (0, Cp - C)))
    grid = (Bp // bm, Cp // bn, n_seg, n_i, n_j)
    psum, sats = pl.pallas_call(
        functools.partial(_kernel, n_seg=n_seg, n_i=n_i, n_j=n_j,
                          adc_lo=adc_lo, adc_hi=adc_hi, bm=bm, bn=bn,
                          b_true=B, c_true=C, narrow=narrow),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, rows_per_xbar), lambda b, c, s, i, j: (b, s)),
            pl.BlockSpec((1, rows_per_xbar, bn), lambda b, c, s, i, j: (j, s, c)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda b, c, s, i, j: (s, c)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda b, c, s, i, j: (b, c)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Cp), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_p, w_p,
      in_li.astype(jnp.int32).reshape(n_i, 1),
      in_mask.astype(jnp.int32).reshape(n_i, 1),
      mults.astype(jnp.int32), cen_p)
    return psum[:B, :C], sats[0, 0]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
