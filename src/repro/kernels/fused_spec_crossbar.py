"""Pallas TPU kernel: speculation + recovery (paper §4.3) in one launch.

``fused_crossbar.py`` fused the *static*-slicing exact datapath; this
kernel does the same for Dynamic Input Slicing, whose recovery pass is
data-dependent (it replaces exactly the conversions that saturated). One
``pallas_call`` performs, per (batch-tile, col-tile, segment, spec-slice
i, weight-slice j) grid step:

  1. the speculative pass — the i-th spec slice (default 4b-2b-2b) is
     cropped in-kernel with a shift+mask, contracted against the j-th
     weight plane, and clamped by the per-segment signed ADC;
  2. the failure mask — a clamp that hit either ADC bound is a failed
     speculation (paper §3.4: saturation *is* the detection signal);
  3. the recovery pass, unrolled over the slice's bit positions — the
     same input rows re-sliced as 1b planes, each converted and
     recombined with ``rmults[i, t] = 1 << t`` (0 kills bit positions
     past the slice's true width, so one unroll length serves ragged
     spec slicings);
  4. the select: recovered values replace failed speculative ones, then
     the digital shift+add via ``mults[i, j] = valid_j << (l_i + l_j)``;
  5. work accounting, analytically from the mask: per-spec-slice failure
     counts (lane-accumulated into a resident (1, n_i) output so the
     host can bill ``width_i`` recovery converts per failure — ADCs for
     columns that speculated successfully are power-gated) and the
     recovery-saturation count (accepted fidelity losses), both masked
     to the true (B, C) extent so tile padding never inflates them;
  6. the digital center term ``phi * sum(x)``, once per segment.

The crossbar always runs every recovery cycle — the kernel mirrors the
hardware by always computing the recovery dots — but only *failed*
columns consume ADC converts, which is what ``SpeculationStats`` bills.
Bit-exact vs the ``core.speculation.forward`` Python loop at noise 0:
in-range column sums are far below 2^24 so ``adc.convert``'s float32
round is the identity on them.

Grid: (B/bm, C/bn, n_seg, n_i, n_j) — the input block's index map
ignores (c, i, j), so Pallas keeps it resident while every spec slice
and recovery bit is cropped from it; the psum accumulates in a VMEM
scratch and flushes once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS_PER_XBAR = 512
DEFAULT_BM = 128
DEFAULT_BN = 256


def _kernel(x_ref, w_ref, li_ref, mask_ref, mult_ref, rmult_ref, cen_ref,
            o_ref, fail_ref, rsat_ref, acc_ref, *,
            n_seg: int, n_i: int, n_j: int, max_w: int,
            adc_lo: int, adc_hi: int,
            bm: int, bn: int, b_true: int, c_true: int, narrow: bool):
    b = pl.program_id(0)
    c = pl.program_id(1)
    s = pl.program_id(2)
    i = pl.program_id(3)
    j = pl.program_id(4)
    first = (s == 0) & (i == 0) & (j == 0)

    @pl.when(first)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(first & (b == 0) & (c == 0))
    def _init_counters():
        fail_ref[...] = jnp.zeros_like(fail_ref)
        rsat_ref[0, 0] = jnp.zeros((), jnp.int32)

    x = x_ref[...]  # (bm, rows_per_xbar) int32, unsigned 8b codes
    w = w_ref[0]    # (rows_per_xbar, bn) int8 signed plane

    # digital center term: phi * sum_r(x), once per (b, c, s)
    @pl.when((i == 0) & (j == 0))
    def _center():
        acc_ref[...] += x.sum(axis=1, keepdims=True) * cen_ref[0]

    li = li_ref[0, 0]

    # --- speculative pass: crop slice i, contract, per-segment ADC clamp
    x_i = jax.lax.shift_right_logical(x, li) & mask_ref[0, 0]
    if narrow:  # every spec-slice value < 128 -> int8 x int8 MXU dot
        cs = jax.lax.dot(x_i.astype(jnp.int8), w,
                         preferred_element_type=jnp.int32)
    else:
        cs = jax.lax.dot(x_i, w.astype(jnp.int32),
                         preferred_element_type=jnp.int32)
    cs = jnp.clip(cs, adc_lo, adc_hi)

    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + b * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + c * bn
    in_bounds = (rows < b_true) & (cols < c_true)
    sat = (cs == adc_lo) | (cs == adc_hi)  # the failure/detection signal

    # per-spec-slice failure count, lane-accumulated into the resident
    # (1, n_i) output (the host bills width_i recovery converts each)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, n_i), 1)
    fail_cnt = (sat & in_bounds).astype(jnp.int32).sum()
    fail_ref[...] += jnp.where(lane == i, fail_cnt, 0)

    # --- recovery pass: the slice re-processed as 1b sub-slices. The
    # unroll runs to the *max* width; rmult = 0 marks bit positions past
    # this slice's true width (no value, no accounting).
    rec = jnp.zeros_like(cs)
    rsat_cnt = jnp.zeros((), jnp.int32)
    for t in range(max_w):
        rm = rmult_ref[0, t]
        x_b = jax.lax.shift_right_logical(x, li + t) & 1
        rcs = jax.lax.dot(x_b.astype(jnp.int8), w,
                          preferred_element_type=jnp.int32)
        rcs = jnp.clip(rcs, adc_lo, adc_hi)
        rec += rcs * rm
        r_sat = (rcs == adc_lo) | (rcs == adc_hi)
        # recovery saturations only count where recovery actually ran
        # (speculation failed) and the bit position is real
        cnt = (r_sat & sat & in_bounds).astype(jnp.int32).sum()
        rsat_cnt += jnp.where(rm > 0, cnt, 0)
    rsat_ref[0, 0] += rsat_cnt

    value = jnp.where(sat, rec, cs)       # recovered where failed
    acc_ref[...] += value * mult_ref[0, 0]  # digital shift+add

    last = (s == n_seg - 1) & (i == n_i - 1) & (j == n_j - 1)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "adc_lo", "adc_hi", "bm", "bn", "rows_per_xbar", "narrow", "interpret"))
def fused_spec_crossbar(x_u8: jnp.ndarray, w_planes: jnp.ndarray,
                        spec_li: jnp.ndarray, spec_mask: jnp.ndarray,
                        mults: jnp.ndarray, rmults: jnp.ndarray,
                        centers: jnp.ndarray, *,
                        adc_lo: int = -64, adc_hi: int = 63,
                        bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                        rows_per_xbar: int = ROWS_PER_XBAR,
                        narrow: bool = True, interpret: bool = True
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused speculation/recovery forward.

    x_u8:     (B, R) int32 — unsigned 8b input codes (R = true rows).
    w_planes: (n_j, Rp, C) int8 — signed slice planes, Rp a multiple of
              ``rows_per_xbar`` >= R (zero row padding is exact).
    spec_li:  (n_i,) int32 — per spec slice, the low bit index l_i.
    spec_mask:(n_i,) int32 — per spec slice, (1 << width_i) - 1.
    mults:    (n_i, n_j) int32 — recombination multipliers; 0 kills a
              padded weight slice entirely.
    rmults:   (n_i, max_w) int32 — recovery recombination; row i holds
              ``1 << t`` for t < width_i, 0 past it.
    centers:  (n_seg, C) int32 — per-segment Center+Offset phi.
    narrow:   every spec-slice width < 8 (values fit int8).

    Returns (psum (B, C) int32 including the center term and the
    recovered-value selects, spec_failures (n_i,) int32 per spec slice,
    recovery_saturations () int32).
    """
    B, R = x_u8.shape
    n_j, Rp, C = w_planes.shape
    assert Rp % rows_per_xbar == 0 and Rp >= R, (Rp, R)
    n_seg = Rp // rows_per_xbar
    n_i = spec_li.shape[0]
    max_w = rmults.shape[1]
    bm = min(bm, _rup(B, 8))
    bn = min(bn, _rup(C, 128))
    Bp, Cp = _rup(B, bm), _rup(C, bn)
    x_p = jnp.pad(x_u8.astype(jnp.int32), ((0, Bp - B), (0, Rp - R)))
    w_p = jnp.pad(w_planes, ((0, 0), (0, 0), (0, Cp - C)))
    cen_p = jnp.pad(centers.astype(jnp.int32), ((0, 0), (0, Cp - C)))
    grid = (Bp // bm, Cp // bn, n_seg, n_i, n_j)
    psum, fails, rsats = pl.pallas_call(
        functools.partial(_kernel, n_seg=n_seg, n_i=n_i, n_j=n_j,
                          max_w=max_w, adc_lo=adc_lo, adc_hi=adc_hi,
                          bm=bm, bn=bn, b_true=B, c_true=C, narrow=narrow),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, rows_per_xbar), lambda b, c, s, i, j: (b, s)),
            pl.BlockSpec((1, rows_per_xbar, bn),
                         lambda b, c, s, i, j: (j, s, c)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, j)),
            pl.BlockSpec((1, max_w), lambda b, c, s, i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda b, c, s, i, j: (s, c)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda b, c, s, i, j: (b, c)),
            pl.BlockSpec((1, n_i), lambda b, c, s, i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Cp), jnp.int32),
            jax.ShapeDtypeStruct((1, n_i), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_p, w_p,
      spec_li.astype(jnp.int32).reshape(n_i, 1),
      spec_mask.astype(jnp.int32).reshape(n_i, 1),
      mults.astype(jnp.int32), rmults.astype(jnp.int32), cen_p)
    return psum[:B, :C], fails[0], rsats[0, 0]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
