"""Pallas TPU kernel: RAELLA sliced-crossbar contraction (PIM sim hot spot).

The bit-exact accelerator simulation spends nearly all its time computing,
for every (input-slice i, weight-slice j) pair and every 512-row crossbar
segment s, the signed column sums

    cs[i, j, s, b, c] = sum_r x_slices[i, b, 512*s + r] * w_planes[j, 512*s + r, c]

then clamping each to the ADC range and shift+adding into int32 psums. The
slice values are tiny integers, so every column-sum block is an int8 x int8
MXU matmul; the ADC clamp + shift+add is a cheap VPU epilogue. This kernel
fuses the whole contraction so column sums never round-trip to HBM.

Hardware mapping notes (TPU adaptation of the PIM algorithm):
  - the 512-row crossbar segment IS the K block: the ADC's non-associative
    clamp forces K-blocking at exactly 512, which conveniently matches MXU-
    friendly tiling (512 = 4 x 128).
  - slice pairs (i, j) are additional grid axes that revisit the same output
    block, accumulating in VMEM — slices never materialize separate outputs.

Grid: (B/bm, C/bn, n_seg, n_i, n_j), output revisited across the last three.
VMEM at defaults (bm=128, bn=256): x 128*512 + w 512*256 int8 = 192 KiB,
acc 128*256 int32 = 128 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS_PER_XBAR = 512
DEFAULT_BM = 128
DEFAULT_BN = 256


def _kernel(x_ref, w_ref, mult_ref, o_ref, acc_ref, *,
            n_seg: int, n_i: int, n_j: int, adc_lo: int, adc_hi: int):
    s = pl.program_id(2)
    i = pl.program_id(3)
    j = pl.program_id(4)
    first = (s == 0) & (i == 0) & (j == 0)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cs = jax.lax.dot(x_ref[0], w_ref[0],
                     preferred_element_type=jnp.int32)  # (bm, bn)
    cs = jnp.clip(cs, adc_lo, adc_hi)                   # the per-segment ADC
    acc_ref[...] += cs * mult_ref[0, 0]

    last = (s == n_seg - 1) & (i == n_i - 1) & (j == n_j - 1)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("adc_lo", "adc_hi", "bm", "bn",
                                             "rows_per_xbar", "interpret"))
def sliced_crossbar_matmul(x_slices: jnp.ndarray, w_planes: jnp.ndarray,
                           mults: jnp.ndarray, *,
                           adc_lo: int = -64, adc_hi: int = 63,
                           bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                           rows_per_xbar: int = ROWS_PER_XBAR,
                           interpret: bool = True) -> jnp.ndarray:
    """x_slices (n_i, B, R) int8, w_planes (n_j, R, C) int8,
    mults (n_i, n_j) int32 -> psums (B, C) int32.

    Zero row padding is exact (zero sliced products clamp to zero).
    """
    n_i, B, R = x_slices.shape
    n_j, R2, C = w_planes.shape
    assert R == R2, (R, R2)
    n_seg = -(-R // rows_per_xbar)
    Rp = n_seg * rows_per_xbar
    bm = min(bm, _rup(B, 8))
    bn = min(bn, _rup(C, 128))
    Bp, Cp = _rup(B, bm), _rup(C, bn)
    x_p = jnp.pad(x_slices, ((0, 0), (0, Bp - B), (0, Rp - R)))
    w_p = jnp.pad(w_planes, ((0, 0), (0, Rp - R), (0, Cp - C)))
    grid = (Bp // bm, Cp // bn, n_seg, n_i, n_j)
    out = pl.pallas_call(
        functools.partial(_kernel, n_seg=n_seg, n_i=n_i, n_j=n_j,
                          adc_lo=adc_lo, adc_hi=adc_hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, rows_per_xbar),
                         lambda b, c, s, i, j: (i, b, s)),
            pl.BlockSpec((1, rows_per_xbar, bn),
                         lambda b, c, s, i, j: (j, s, c)),
            pl.BlockSpec((1, 1), lambda b, c, s, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda b, c, s, i, j: (b, c)),
        out_shape=jax.ShapeDtypeStruct((Bp, Cp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_p, w_p, mults.astype(jnp.int32))
    return out[:B, :C]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
