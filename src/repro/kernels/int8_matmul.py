"""Pallas TPU kernel: centered int8 matmul (paper Eq. 1, TPU-native).

    y[b, n] = sum_k x[b, k] * w_off[k, n]  +  (sum_k x[b, k]) * centers[n]

int8 operands feed the MXU (int8 x int8 -> int32); the rank-1 center term
is a VPU epilogue fused into the final K step. Tiled over (B, N, K) with
MXU-aligned blocks; the x-tile, w-tile, accumulator and row-sum scratch all
live in VMEM.

VMEM budget at defaults (bm=256, bk=512, bn=256):
  x tile 256*512 int8 = 128 KiB, w tile 512*256 int8 = 128 KiB,
  acc 256*256 int32 = 256 KiB, rowsum 256*1 int32 = 1 KiB  -> ~0.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256


def _kernel(x_ref, w_ref, c_ref, o_ref, acc_ref, xsum_ref, *, n_k: int):
    """Grid: (B/bm, N/bn, K/bk) — K innermost so the accumulator stays hot."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)

    x = x_ref[...]
    acc_ref[...] += jax.lax.dot(
        x, w_ref[...], preferred_element_type=jnp.int32)
    xsum_ref[...] += x.astype(jnp.int32).sum(axis=1, keepdims=True)

    @pl.when(k == n_k - 1)
    def _epilogue():
        centers = c_ref[...].astype(jnp.int32)  # (1, bn)
        o_ref[...] = acc_ref[...] + xsum_ref[...] * centers


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def centered_int8_matmul(x_q: jnp.ndarray, w_off: jnp.ndarray,
                         centers: jnp.ndarray, *,
                         bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                         bn: int = DEFAULT_BN,
                         interpret: bool = True) -> jnp.ndarray:
    """x_q (B, K) int8, w_off (K, N) int8, centers (N,) int32 -> (B, N) int32.

    Shapes are padded up to block multiples; zero padding is exact for this
    contraction (zero rows/cols contribute nothing, including to rowsum).
    """
    B, K = x_q.shape
    K2, N = w_off.shape
    assert K == K2, (K, K2)
    bm, bk, bn = min(bm, _rup(B, 8)), min(bk, _rup(K, 128)), min(bn, _rup(N, 128))
    Bp, Kp, Np = _rup(B, bm), _rup(K, bk), _rup(N, bn)
    x_p = jnp.pad(x_q, ((0, Bp - B), (0, Kp - K)))
    w_p = jnp.pad(w_off, ((0, Kp - K), (0, Np - N)))
    c_p = jnp.pad(centers.astype(jnp.int32), (0, Np - N))[None, :]  # (1, Np)
    n_k = Kp // bk
    grid = (Bp // bm, Np // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x_p, w_p, c_p)
    return out[:B, :N]


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m
