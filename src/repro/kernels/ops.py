"""Kernel-backend registry + jit'd public wrappers for the Pallas kernels.

Every op is registered under one or more *backends*:

  xla         — the pure-jnp reference from ``ref`` (always available;
                what the 512-device dry-run lowers, since the host CPU
                backend does not lower Pallas TPU kernels).
  interpret   — the Pallas kernel in interpreter mode: bit-identical
                semantics on any backend, slow; what CI forces to catch
                kernel regressions without TPU runners.
  pallas-tpu  — the Pallas kernel lowered natively (requires a TPU).
  pallas-gpu  — reserved; no Triton ports exist yet, so requests fall
                back down the chain below.

Selection order, first match wins:

  1. the ``REPRO_KERNEL_BACKEND`` environment variable (CI override);
  2. the explicit ``backend=`` argument (plumbed from
     ``ArchConfig.pim_kernel_backend`` by the model dispatch path);
  3. ``auto``: ``pallas-tpu`` on TPU, else ``xla``.

Two aliases resolve before lookup: ``auto`` (above) and ``pallas``
(``pallas-tpu`` on TPU, else ``interpret`` — the legacy ``use_pallas``
semantics). A backend not registered for an op falls back to ``xla``,
which exists for every op, so resolution never fails on a valid name.
"""

from __future__ import annotations

import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import fused_crossbar as _fx
from repro.kernels import fused_spec_crossbar as _fs
from repro.kernels import int8_matmul as _im
from repro.kernels import ref as _ref
from repro.kernels import sliced_crossbar as _sx

ENV_VAR = "REPRO_KERNEL_BACKEND"
BACKENDS = ("xla", "interpret", "pallas-tpu", "pallas-gpu")
ALIASES = ("auto", "pallas")

_REGISTRY: dict[str, dict[str, Callable]] = {}


def register(op: str, backend: str, fn: Callable) -> None:
    """Register ``fn`` as the ``backend`` implementation of ``op``."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    _REGISTRY.setdefault(op, {})[backend] = fn


def backends(op: str) -> tuple[str, ...]:
    """Backends registered for ``op`` (resolution may still pick others
    via the xla fallback)."""
    return tuple(sorted(_REGISTRY[op]))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(op: str, request: str | None = None) -> str:
    """Resolve a backend request to a registered backend name for ``op``.

    ``request=None`` means ``auto``. Order: env override, request, auto;
    aliases expand per the module docstring; unregistered backends fall
    back to ``xla``.
    """
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; have {sorted(_REGISTRY)}")
    name = os.environ.get(ENV_VAR) or request or "auto"
    if name not in BACKENDS + ALIASES:
        raise ValueError(
            f"unknown kernel backend {name!r}; expected one of "
            f"{BACKENDS + ALIASES}")
    if name == "auto":
        name = "pallas-tpu" if _on_tpu() else "xla"
    elif name == "pallas":
        name = "pallas-tpu" if _on_tpu() else "interpret"
    if name not in _REGISTRY[op]:
        name = "xla"
    return name


def dispatch(op: str, request: str | None = None) -> Callable:
    return _REGISTRY[op][resolve_backend(op, request)]


# ------------------------------------------------------------------ ops
def centered_int8_matmul(x_q: jnp.ndarray, w_off: jnp.ndarray,
                         centers: jnp.ndarray, *,
                         use_pallas: bool = False,
                         backend: str | None = None) -> jnp.ndarray:
    """y_int32 = x_q @ w_off + rowsum(x_q) * centers (Eq. 1 fast path).

    ``backend`` follows the registry selection order; the legacy
    ``use_pallas`` flag (= backend 'pallas' / 'xla') applies only when
    ``backend`` is not given.
    """
    if backend is None and use_pallas:
        backend = "pallas"
    return dispatch("centered_int8_matmul", backend)(x_q, w_off, centers)


def sliced_crossbar_matmul(x_slices: jnp.ndarray, w_planes: jnp.ndarray,
                           mults: jnp.ndarray, *,
                           adc_lo: int = -64, adc_hi: int = 63,
                           rows_per_xbar: int = 512,
                           use_pallas: bool = False,
                           backend: str | None = None) -> jnp.ndarray:
    """RAELLA crossbar contraction with per-segment ADC clamp."""
    if backend is None and use_pallas:
        backend = "pallas"
    return dispatch("sliced_crossbar_matmul", backend)(
        x_slices, w_planes, mults, adc_lo=adc_lo, adc_hi=adc_hi,
        rows_per_xbar=rows_per_xbar)


def _input_bounds(input_slicing: tuple[int, ...],
                  total_bits: int = 8) -> list[tuple[int, int]]:
    """MSB-first (hi, lo) bit bounds — mirrors ``core.slicing.slice_bounds``
    (kept local so ``repro.kernels`` stays importable without ``repro.core``)."""
    if sum(input_slicing) != total_bits:
        raise ValueError(f"input slicing {input_slicing} must cover "
                         f"{total_bits} bits")
    out, hi = [], total_bits - 1
    for w in input_slicing:
        out.append((hi, hi - w + 1))
        hi -= w
    return out


def fused_crossbar_forward(x_u8: jnp.ndarray, planes: jnp.ndarray,
                           shifts, centers: jnp.ndarray, *,
                           input_slicing: tuple[int, ...],
                           adc_lo: int, adc_hi: int,
                           valid: jnp.ndarray | None = None,
                           rows_per_xbar: int = 512,
                           backend: str | None = None
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused exact-datapath forward: slice-plane matmul + per-segment ADC
    clamp + shift-and-accumulate + digital center term, one op.

    x_u8:     (B, R) unsigned 8b input codes (any int dtype).
    planes:   (n_j, n_seg, rows_per_xbar, C) int8 signed slice planes —
              the ``EncodedWeights.planes`` layout, possibly padded on
              the slice axis by the per-site compiler.
    shifts:   (n_j,) per-slice recombination shifts — a static tuple or
              a traced int32 array (ragged per-site plans).
    centers:  (n_seg, C) int32 Center+Offset phi.
    valid:    optional (n_j,) bool mask for padded slice planes; masked
              planes are zeroed and their multipliers killed, so the
              result is identical to running the unpadded encoding.

    Returns (psum (B, C) int32 including the center term, saturations
    () int32). Bit-exact vs the ``core.crossbar.forward`` Python loop at
    noise 0 for any ADC window containing 0 (the padding contract).
    """
    input_slicing = tuple(int(b) for b in input_slicing)
    bounds = _input_bounds(input_slicing)
    n_j, n_seg, rx, C = planes.shape
    if rx != rows_per_xbar:
        raise ValueError(f"planes rows {rx} != rows_per_xbar {rows_per_xbar}")
    if valid is not None:
        planes = planes * valid[:, None, None, None].astype(planes.dtype)
    w_flat = planes.reshape(n_j, n_seg * rows_per_xbar, C)
    in_li = jnp.asarray([lo for (_, lo) in bounds], jnp.int32)
    in_mask = jnp.asarray([(1 << (hi - lo + 1)) - 1 for (hi, lo) in bounds],
                          jnp.int32)
    shifts_arr = jnp.asarray(shifts, jnp.int32)
    mults = jnp.left_shift(jnp.int32(1),
                           in_li[:, None] + shifts_arr[None, :])
    if valid is not None:
        mults = mults * valid.astype(jnp.int32)[None, :]
    narrow = max(hi - lo + 1 for (hi, lo) in bounds) < 8
    fn = dispatch("fused_crossbar", backend)
    return fn(x_u8.astype(jnp.int32), w_flat, in_li, in_mask, mults,
              centers.astype(jnp.int32), adc_lo=adc_lo, adc_hi=adc_hi,
              rows_per_xbar=rows_per_xbar, narrow=narrow)


def fused_spec_crossbar_forward(x_u8: jnp.ndarray, planes: jnp.ndarray,
                                shifts, centers: jnp.ndarray, *,
                                spec_slicing: tuple[int, ...],
                                adc_lo: int, adc_hi: int,
                                valid: jnp.ndarray | None = None,
                                rows_per_xbar: int = 512,
                                backend: str | None = None
                                ) -> tuple[jnp.ndarray, jnp.ndarray,
                                           jnp.ndarray]:
    """Fused speculation/recovery forward (paper §4.3 Dynamic Input
    Slicing): speculative slice-plane matmul + per-segment ADC clamp +
    failure detection + 1b recovery converts + select + shift-and-
    accumulate + digital center term, one op.

    x_u8:     (B, R) unsigned 8b input codes (any int dtype).
    planes:   (n_j, n_seg, rows_per_xbar, C) int8 signed slice planes —
              the ``EncodedWeights.planes`` layout, possibly padded on
              the slice axis by the per-site compiler.
    shifts:   (n_j,) per-slice recombination shifts — a static tuple or
              a traced int32 array (ragged per-site plans).
    centers:  (n_seg, C) int32 Center+Offset phi.
    spec_slicing: the speculative input slicing, e.g. (4, 2, 2).
    valid:    optional (n_j,) bool mask for padded slice planes; masked
              planes are zeroed and their multipliers killed, so the
              psum is identical to running the unpadded encoding (work
              counters still see every plane — the Python-loop
              contract).

    Returns (psum (B, C) int32 including the center term,
    spec_failures (n_i,) int32 — failed conversions per spec slice, the
    analytic source for recovery-convert billing — and
    recovery_saturations () int32). Bit-exact vs the
    ``core.speculation.forward`` Python loop at noise 0 for any ADC
    window containing 0 (the padding contract).
    """
    spec_slicing = tuple(int(b) for b in spec_slicing)
    bounds = _input_bounds(spec_slicing)
    n_j, n_seg, rx, C = planes.shape
    if rx != rows_per_xbar:
        raise ValueError(f"planes rows {rx} != rows_per_xbar {rows_per_xbar}")
    if valid is not None:
        planes = planes * valid[:, None, None, None].astype(planes.dtype)
    w_flat = planes.reshape(n_j, n_seg * rows_per_xbar, C)
    spec_li = jnp.asarray([lo for (_, lo) in bounds], jnp.int32)
    spec_mask = jnp.asarray([(1 << (hi - lo + 1)) - 1 for (hi, lo) in bounds],
                            jnp.int32)
    shifts_arr = jnp.asarray(shifts, jnp.int32)
    mults = jnp.left_shift(jnp.int32(1),
                           spec_li[:, None] + shifts_arr[None, :])
    if valid is not None:
        mults = mults * valid.astype(jnp.int32)[None, :]
    widths = [hi - lo + 1 for (hi, lo) in bounds]
    max_w = max(widths)
    rmults = jnp.asarray([[(1 << t) if t < w else 0 for t in range(max_w)]
                          for w in widths], jnp.int32)
    narrow = max_w < 8
    fn = dispatch("fused_spec_crossbar", backend)
    return fn(x_u8.astype(jnp.int32), w_flat, spec_li, spec_mask, mults,
              rmults, centers.astype(jnp.int32), adc_lo=adc_lo, adc_hi=adc_hi,
              rows_per_xbar=rows_per_xbar, narrow=narrow)


# ------------------------------------------------------------- registry
def _drop_narrow(fn):
    """The XLA reference needs no narrow/int8 hint — accept and drop it."""
    @functools.wraps(fn)
    def wrapped(*args, narrow=True, **kwargs):
        del narrow
        return fn(*args, **kwargs)
    return wrapped


register("centered_int8_matmul", "xla", _ref.centered_int8_matmul)
register("centered_int8_matmul", "interpret",
         functools.partial(_im.centered_int8_matmul, interpret=True))
register("centered_int8_matmul", "pallas-tpu",
         functools.partial(_im.centered_int8_matmul, interpret=False))

register("sliced_crossbar_matmul", "xla", _ref.sliced_crossbar_matmul)
register("sliced_crossbar_matmul", "interpret",
         functools.partial(_sx.sliced_crossbar_matmul, interpret=True))
register("sliced_crossbar_matmul", "pallas-tpu",
         functools.partial(_sx.sliced_crossbar_matmul, interpret=False))

register("fused_crossbar", "xla", _drop_narrow(_ref.fused_crossbar))
register("fused_crossbar", "interpret",
         functools.partial(_fx.fused_crossbar, interpret=True))
register("fused_crossbar", "pallas-tpu",
         functools.partial(_fx.fused_crossbar, interpret=False))

register("fused_spec_crossbar", "xla", _drop_narrow(_ref.fused_spec_crossbar))
register("fused_spec_crossbar", "interpret",
         functools.partial(_fs.fused_spec_crossbar, interpret=True))
register("fused_spec_crossbar", "pallas-tpu",
         functools.partial(_fs.fused_spec_crossbar, interpret=False))
