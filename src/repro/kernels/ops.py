"""Jit'd public wrappers for the Pallas kernels.

Every op takes ``use_pallas``: True runs the Pallas kernel (interpret mode on
CPU — bit-identical semantics, real TPU lowering on device), False runs the
pure-XLA fallback from ``ref`` (what the 512-device dry-run lowers, since the
host CPU backend does not lower Pallas TPU kernels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import int8_matmul as _im
from repro.kernels import ref as _ref
from repro.kernels import sliced_crossbar as _sx


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def centered_int8_matmul(x_q: jnp.ndarray, w_off: jnp.ndarray,
                         centers: jnp.ndarray, *,
                         use_pallas: bool = False) -> jnp.ndarray:
    """y_int32 = x_q @ w_off + rowsum(x_q) * centers (Eq. 1 fast path)."""
    if use_pallas:
        return _im.centered_int8_matmul(x_q, w_off, centers,
                                        interpret=not _on_tpu())
    return _ref.centered_int8_matmul(x_q, w_off, centers)


def sliced_crossbar_matmul(x_slices: jnp.ndarray, w_planes: jnp.ndarray,
                           mults: jnp.ndarray, *,
                           adc_lo: int = -64, adc_hi: int = 63,
                           rows_per_xbar: int = 512,
                           use_pallas: bool = False) -> jnp.ndarray:
    """RAELLA crossbar contraction with per-segment ADC clamp."""
    if use_pallas:
        return _sx.sliced_crossbar_matmul(
            x_slices, w_planes, mults, adc_lo=adc_lo, adc_hi=adc_hi,
            rows_per_xbar=rows_per_xbar, interpret=not _on_tpu())
    return _ref.sliced_crossbar_matmul(
        x_slices, w_planes, mults, adc_lo=adc_lo, adc_hi=adc_hi,
        rows_per_xbar=rows_per_xbar)
