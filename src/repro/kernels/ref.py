"""Pure-jnp oracles for the Pallas kernels (ground truth for tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def centered_int8_matmul(x_q: jnp.ndarray, w_off: jnp.ndarray,
                         centers: jnp.ndarray) -> jnp.ndarray:
    """y = x_q @ w_off + rowsum(x_q) * centers   (all int32).

    x_q: (B, K) int8; w_off: (K, N) int8; centers: (N,) int32.
    The TPU-native form of the paper's Eq. 1: offsets on the MXU, the
    rank-1 center term digital.
    """
    acc = jnp.dot(x_q.astype(jnp.int32), w_off.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    xsum = x_q.astype(jnp.int32).sum(axis=-1, keepdims=True)
    return acc + xsum * centers[None, :].astype(jnp.int32)


def sliced_crossbar_matmul(x_slices: jnp.ndarray, w_planes: jnp.ndarray,
                           mults: jnp.ndarray, *,
                           rows_per_xbar: int = 512,
                           adc_lo: int = -64, adc_hi: int = 63) -> jnp.ndarray:
    """Bit-exact RAELLA crossbar contraction (the PIM-simulation hot spot).

    x_slices: (n_i, B, R) int8  — unsigned input-slice values (0..15).
    w_planes: (n_j, R, C) int8  — signed weight-slice values (-15..15).
    mults:    (n_i, n_j) int32  — 2**(l_i + l_j) recombination multipliers.
    Per 512-row segment, each (i, j) column sum is clamped by the ADC before
    the digital shift+add — the contraction is deliberately non-associative
    across segments (each segment has its own ADC).

    Returns (B, C) int32 psums of the offset term (no center term).
    """
    n_i, B, R = x_slices.shape
    n_j, _, C = w_planes.shape
    n_seg = -(-R // rows_per_xbar)
    pad = n_seg * rows_per_xbar - R
    x_p = jnp.pad(x_slices, ((0, 0), (0, 0), (0, pad)))
    w_p = jnp.pad(w_planes, ((0, 0), (0, pad), (0, 0)))
    xs = x_p.reshape(n_i, B, n_seg, rows_per_xbar)
    ws = w_p.reshape(n_j, n_seg, rows_per_xbar, C)
    out = jnp.zeros((B, C), jnp.int32)
    for i in range(n_i):
        for j in range(n_j):
            cs = jnp.einsum("bsr,src->bsc", xs[i].astype(jnp.int32),
                            ws[j].astype(jnp.int32),
                            preferred_element_type=jnp.int32)
            cs = jnp.clip(cs, adc_lo, adc_hi)  # per-segment ADC
            out = out + cs.sum(axis=1) * mults[i, j]
    return out


def fused_crossbar(x_u8: jnp.ndarray, w_planes: jnp.ndarray,
                   in_li: jnp.ndarray, in_mask: jnp.ndarray,
                   mults: jnp.ndarray, centers: jnp.ndarray, *,
                   rows_per_xbar: int = 512,
                   adc_lo: int = -64,
                   adc_hi: int = 63) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-XLA reference for ``fused_crossbar.fused_crossbar``.

    Same contract as the Pallas kernel: x_u8 (B, R) int32 unsigned 8b
    codes, w_planes (n_j, Rp, C) int8 with Rp a rows_per_xbar multiple,
    in_li / in_mask (n_i,) int32 input-slice crop tables, mults
    (n_i, n_j) int32 recombination multipliers (0 = padded slice),
    centers (n_seg, C) int32. Returns (psum (B, C) int32 including the
    digital center term, saturation count () int32).
    """
    B, R = x_u8.shape
    n_j, Rp, C = w_planes.shape
    n_seg = Rp // rows_per_xbar
    n_i = in_li.shape[0]
    xs = jnp.pad(x_u8.astype(jnp.int32), ((0, 0), (0, Rp - R)))
    xs = xs.reshape(B, n_seg, rows_per_xbar)
    ws = w_planes.reshape(n_j, n_seg, rows_per_xbar, C).astype(jnp.int32)
    out = jnp.einsum("bsr,sc->bc", xs, centers.astype(jnp.int32),
                     preferred_element_type=jnp.int32)  # center term
    sats = jnp.zeros((), jnp.int32)
    for i in range(n_i):
        x_i = jax.lax.shift_right_logical(xs, in_li[i]) & in_mask[i]
        for j in range(n_j):
            cs = jnp.einsum("bsr,src->bsc", x_i, ws[j],
                            preferred_element_type=jnp.int32)
            cs = jnp.clip(cs, adc_lo, adc_hi)  # per-segment ADC
            sats = sats + ((cs == adc_lo) | (cs == adc_hi)).sum()
            out = out + cs.sum(axis=1) * mults[i, j]
    return out, sats


def fused_spec_crossbar(x_u8: jnp.ndarray, w_planes: jnp.ndarray,
                        spec_li: jnp.ndarray, spec_mask: jnp.ndarray,
                        mults: jnp.ndarray, rmults: jnp.ndarray,
                        centers: jnp.ndarray, *,
                        rows_per_xbar: int = 512,
                        adc_lo: int = -64,
                        adc_hi: int = 63
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pure-XLA reference for ``fused_spec_crossbar.fused_spec_crossbar``.

    Speculation + recovery (paper §4.3): each spec slice i is converted
    once per weight plane j; conversions that clamp at an ADC bound are
    *failures* whose value is replaced by the 1b recovery recombination
    ``sum_t clip(x_bit_t @ w_j) * rmults[i, t]``. Failure counts are per
    spec slice (the host bills ``width_i`` recovery converts each);
    recovery saturations count only where recovery actually ran.

    Same contract as the Pallas kernel — see its docstring for the
    argument shapes. Returns (psum (B, C) int32, spec_failures (n_i,)
    int32, recovery_saturations () int32).
    """
    B, R = x_u8.shape
    n_j, Rp, C = w_planes.shape
    n_seg = Rp // rows_per_xbar
    n_i = spec_li.shape[0]
    max_w = rmults.shape[1]
    xs = jnp.pad(x_u8.astype(jnp.int32), ((0, 0), (0, Rp - R)))
    xs = xs.reshape(B, n_seg, rows_per_xbar)
    ws = w_planes.reshape(n_j, n_seg, rows_per_xbar, C).astype(jnp.int32)
    out = jnp.einsum("bsr,sc->bc", xs, centers.astype(jnp.int32),
                     preferred_element_type=jnp.int32)  # center term
    fails = []
    rsats = jnp.zeros((), jnp.int32)
    for i in range(n_i):
        x_i = jax.lax.shift_right_logical(xs, spec_li[i]) & spec_mask[i]
        fail_i = jnp.zeros((), jnp.int32)
        for j in range(n_j):
            cs = jnp.einsum("bsr,src->bsc", x_i, ws[j],
                            preferred_element_type=jnp.int32)
            cs = jnp.clip(cs, adc_lo, adc_hi)  # per-segment ADC
            sat = (cs == adc_lo) | (cs == adc_hi)
            fail_i = fail_i + sat.astype(jnp.int32).sum()
            rec = jnp.zeros_like(cs)
            for t in range(max_w):
                x_b = jax.lax.shift_right_logical(xs, spec_li[i] + t) & 1
                rcs = jnp.einsum("bsr,src->bsc", x_b, ws[j],
                                 preferred_element_type=jnp.int32)
                rcs = jnp.clip(rcs, adc_lo, adc_hi)
                rec = rec + rcs * rmults[i, t]
                r_sat = (rcs == adc_lo) | (rcs == adc_hi)
                cnt = (r_sat & sat).astype(jnp.int32).sum()
                rsats = rsats + jnp.where(rmults[i, t] > 0, cnt, 0)
            value = jnp.where(sat, rec, cs)
            out = out + value.sum(axis=1) * mults[i, j]
        fails.append(fail_i)
    return out, jnp.stack(fails), rsats
