"""Metric export: Prometheus text exposition + JSON snapshot files.

Two faces over one :class:`repro.obs.metrics.MetricsRegistry`:

- :func:`to_prometheus` renders the standard text exposition format
  (``# HELP`` / ``# TYPE`` headers, label escaping, cumulative
  ``_bucket{le=...}`` / ``_sum`` / ``_count`` histogram series) — what a
  scrape endpoint or pushgateway would serve;
- :func:`write_metrics` writes the ``serve --metrics-out`` document: a
  JSON object carrying the structured snapshot *and* the Prometheus text
  (so one file feeds both dashboards and ad-hoc ``promtool``-style
  checks).
"""

from __future__ import annotations

import json

from repro.obs.metrics import Histogram, MetricsRegistry


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


def _labelstr(labels: dict, extra: tuple[tuple[str, str], ...] = ()) -> str:
    items = [(k, labels[k]) for k in sorted(labels)] + list(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items) + "}"


def _fmt(v) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _fmt_le(ub: float) -> str:
    return str(int(ub)) if float(ub).is_integer() else repr(float(ub))


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render every registered series in Prometheus text exposition."""
    lines: list[str] = []
    for m in registry.metrics():
        lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            for labels, s in m.series():
                for ub, c in zip(m.buckets, s["counts"]):
                    lines.append(
                        f"{m.name}_bucket"
                        f"{_labelstr(labels, (('le', _fmt_le(ub)),))} {c}")
                lines.append(
                    f"{m.name}_bucket{_labelstr(labels, (('le', '+Inf'),))}"
                    f" {s['counts'][-1]}")
                lines.append(f"{m.name}_sum{_labelstr(labels)} "
                             f"{_fmt(s['sum'])}")
                lines.append(f"{m.name}_count{_labelstr(labels)} "
                             f"{s['count']}")
        else:
            for labels, v in m.series():
                lines.append(f"{m.name}{_labelstr(labels)} {_fmt(v)}")
    return "\n".join(lines) + "\n"


def snapshot(registry: MetricsRegistry) -> dict:
    """Structured JSON-serializable dump (delegates to the registry)."""
    return registry.snapshot()


def metrics_document(registry: MetricsRegistry, **extra) -> dict:
    """The ``--metrics-out`` document: snapshot + exposition + context
    (config, engine stats, ...) passed as keyword blocks."""
    doc = {"metrics": registry.snapshot(),
           "prometheus": to_prometheus(registry)}
    doc.update(extra)
    return doc


def write_metrics(registry: MetricsRegistry, path: str, **extra) -> dict:
    doc = metrics_document(registry, **extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return doc
