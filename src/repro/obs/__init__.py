"""repro.obs — unified telemetry: metrics, tracing, profiling hooks.

One low-overhead subsystem every layer reports through: a label-set
metrics registry with Prometheus/JSON export (``repro.obs.metrics``,
``repro.obs.export``), a Chrome-trace span recorder
(``repro.obs.tracing``), and the serve-stack binding that threads both
through the schedulers plus the PIM work counters and the §2.5 energy
model (``repro.obs.serve``).
"""

from repro.obs.export import (  # noqa: F401
    metrics_document,
    snapshot,
    to_prometheus,
    write_metrics,
)
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.serve import (  # noqa: F401
    NULL_TELEMETRY,
    ServeTelemetry,
    record_pim_totals,
)
from repro.obs.tracing import Tracer  # noqa: F401

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TELEMETRY",
    "ServeTelemetry",
    "Tracer",
    "metrics_document",
    "record_pim_totals",
    "snapshot",
    "to_prometheus",
    "write_metrics",
]
