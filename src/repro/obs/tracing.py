"""Per-request tracing: Chrome-trace (Perfetto-loadable) event log.

The tracer records *host-observed* intervals — the serve engines already
batch every device read into one ``jax.device_get`` per iteration, so a
span's duration is the wall time between the host syncs the engine was
doing anyway. Tracing never adds a device sync.

Events follow the Chrome Trace Event format (the JSON ``traceEvents``
array Perfetto / ``chrome://tracing`` load directly):

- complete events (``ph: "X"``) for spans, with ``ts``/``dur`` in
  microseconds;
- instant events (``ph: "i"``) for point occurrences (prefix hits,
  evictions, first tokens);
- one metadata event per track naming the lane.

Track convention (see ``docs/observability.md`` for the span taxonomy):
``tid 0`` is the engine lane (admission / prefill_chunk / decode_step);
each request gets its own lane at ``tid = uid + 1`` (queue_wait /
request / instants), so a Perfetto timeline shows scheduler occupancy
above a per-request Gantt chart.

A disabled tracer (``Tracer(enabled=False)``) makes every call a no-op;
``clock`` is injectable so tests pin deterministic timestamps.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Callable

ENGINE_TID = 0


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


class Tracer:
    def __init__(self, enabled: bool = True, *, pid: int = 0,
                 clock_us: Callable[[], float] | None = None):
        self.enabled = enabled
        self.pid = pid
        self._clock = clock_us or _now_us
        self._events: list[dict] = []
        self._track_names: dict[int, str] = {}

    # ------------------------------------------------------------ recording
    def now(self) -> float:
        """Current trace timestamp (microseconds)."""
        return self._clock()

    def name_track(self, tid: int, name: str) -> None:
        if self.enabled:
            self._track_names.setdefault(tid, name)

    def complete(self, name: str, ts: float, dur: float, *,
                 tid: int = ENGINE_TID, cat: str = "serve",
                 **args) -> None:
        """One finished span with explicit start/duration (used for spans
        whose start predates the call, e.g. queue_wait at admit time)."""
        if self.enabled:
            self._events.append({
                "name": name, "cat": cat, "ph": "X", "ts": ts,
                "dur": max(dur, 0.0), "pid": self.pid, "tid": tid,
                "args": args})

    @contextlib.contextmanager
    def span(self, name: str, *, tid: int = ENGINE_TID, cat: str = "serve",
             **args):
        if not self.enabled:
            yield
            return
        t0 = self._clock()
        try:
            yield
        finally:
            self.complete(name, t0, self._clock() - t0, tid=tid, cat=cat,
                          **args)

    def instant(self, name: str, *, tid: int = ENGINE_TID,
                cat: str = "serve", **args) -> None:
        if self.enabled:
            self._events.append({
                "name": name, "cat": cat, "ph": "i", "ts": self._clock(),
                "s": "t", "pid": self.pid, "tid": tid, "args": args})

    # ------------------------------------------------------------- export
    def events(self) -> list[dict]:
        return list(self._events)

    def chrome_trace(self) -> dict:
        """The full Perfetto-loadable document."""
        meta = [{"name": "thread_name", "ph": "M", "pid": self.pid,
                 "tid": tid, "args": {"name": name}}
                for tid, name in sorted(self._track_names.items())]
        return {"traceEvents": meta + self._events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
