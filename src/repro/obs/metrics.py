"""Metrics registry: counters / gauges / histograms with label sets.

The registry is the one place every subsystem reports through — serve
engines, PIM work counters, benchmark drivers. Design constraints:

- **Near-zero cost when disabled.** ``MetricsRegistry(enabled=False)``
  hands out a shared no-op metric; every ``inc``/``set``/``observe`` is
  one attribute lookup + an empty method call, no locks, no dict churn.
  Engines therefore thread a registry unconditionally instead of
  guarding every call site.
- **Thread-safe.** One registry lock guards series creation and every
  update (serve loops are single-threaded today, but benchmark drivers
  and future async schedulers are not).
- **Two export faces.** :func:`repro.obs.export.to_prometheus` renders
  the standard text exposition; :meth:`MetricsRegistry.snapshot` returns
  a JSON-serializable dict (the shape ``benchmarks/run.py --record``
  stores).

Labels follow the Prometheus model: a metric is declared once with its
label *names*; each distinct label-*value* tuple is an independent
series. Histograms use cumulative ``le`` buckets (upper-bound
inclusive), matching Prometheus semantics exactly so the exposition
needs no re-bucketing.
"""

from __future__ import annotations

import threading

# serve-latency oriented default buckets (seconds); +Inf is implicit
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class _NullMetric:
    """Shared no-op stand-in handed out by a disabled registry."""

    __slots__ = ()

    def inc(self, value=1, **labels):
        pass

    def set(self, value, **labels):
        pass

    def observe(self, value, **labels):
        pass

    def get(self, **labels):
        return 0.0


_NULL = _NullMetric()


class Metric:
    """One named metric: a family of series keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...],
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._lock = lock
        self._series: dict[tuple, float] = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, declared "
                f"{sorted(self.labelnames)}")
        return tuple(labels[n] for n in self.labelnames)

    def get(self, **labels) -> float:
        return self._series.get(self._key(labels), 0.0)

    def series(self) -> list[tuple[dict, float]]:
        with self._lock:
            return [(dict(zip(self.labelnames, k)), v)
                    for k, v in sorted(self._series.items())]


class Counter(Metric):
    kind = "counter"

    def inc(self, value=1, **labels) -> None:
        if value < 0:
            raise ValueError(f"{self.name}: counters only go up ({value})")
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + value


class Gauge(Metric):
    kind = "gauge"

    def set(self, value, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = value

    def inc(self, value=1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + value


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus ``le`` semantics).

    Each series holds per-bucket counts (a value lands in every bucket
    whose upper bound is >= it, plus the implicit +Inf), a running sum,
    and a total count.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        b = tuple(sorted(float(x) for x in buckets))
        if len(set(b)) != len(b) or not b:
            raise ValueError(f"{name}: buckets must be distinct, non-empty")
        self.buckets = b
        self._series: dict[tuple, dict] = {}

    def _blank(self) -> dict:
        return {"counts": [0] * (len(self.buckets) + 1), "sum": 0.0,
                "count": 0}

    def observe(self, value, **labels) -> None:
        key = self._key(labels)
        value = float(value)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = self._blank()
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    s["counts"][i] += 1
            s["counts"][-1] += 1          # +Inf
            s["sum"] += value
            s["count"] += 1

    def get(self, **labels) -> dict:
        s = self._series.get(self._key(labels))
        return dict(s) if s else self._blank()

    def series(self) -> list[tuple[dict, dict]]:
        with self._lock:
            return [(dict(zip(self.labelnames, k)),
                     {"counts": list(v["counts"]), "sum": v["sum"],
                      "count": v["count"]})
                    for k, v in sorted(self._series.items())]


class MetricsRegistry:
    """Declare-once, update-anywhere metric store.

    ``counter``/``gauge``/``histogram`` return the existing metric on
    re-declaration (idempotent, so library code can declare at call
    sites) but refuse a re-declaration that changes type, labels, or
    buckets — silent schema drift is exactly what this subsystem exists
    to prevent.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._metrics: dict[str, Metric] = {}

    def _declare(self, cls, name, help, labelnames, **kw):
        if not self.enabled:
            return _NULL
        labelnames = tuple(labelnames)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                same = (type(m) is cls and m.labelnames == labelnames
                        and (cls is not Histogram
                             or m.buckets == tuple(sorted(
                                 float(b) for b in kw.get(
                                     "buckets", DEFAULT_BUCKETS)))))
                if not same:
                    raise ValueError(
                        f"metric {name!r} re-declared with a different "
                        f"type/labels/buckets")
                return m
            m = cls(name, help, labelnames, self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames=()) -> Counter:
        return self._declare(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> Gauge:
        return self._declare(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._declare(Histogram, name, help, labelnames,
                             buckets=buckets)

    def metrics(self) -> list[Metric]:
        with self._lock:
            return [self._metrics[n] for n in sorted(self._metrics)]

    def snapshot(self) -> dict:
        """JSON-serializable dump of every series (the ``--record``
        schema's ``metrics`` block)."""
        out: dict = {}
        for m in self.metrics():
            entry = {"type": m.kind, "help": m.help,
                     "labelnames": list(m.labelnames)}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
                entry["series"] = [{"labels": lab, **val}
                                   for lab, val in m.series()]
            else:
                entry["series"] = [{"labels": lab, "value": val}
                                   for lab, val in m.series()]
            out[m.name] = entry
        return out
