"""Serve-stack telemetry: request lifecycle metrics, spans, PIM depth.

:class:`ServeTelemetry` is the object the serve engines thread through
their scheduler loops (``ContinuousServeEngine(..., telemetry=...)``).
It owns a :class:`repro.obs.metrics.MetricsRegistry` and an optional
:class:`repro.obs.tracing.Tracer`, and exposes the small hook surface
the engines call:

- request lifecycle — ``on_submit`` / ``on_admit`` / ``on_token`` /
  ``on_finish`` drive the queue-wait, TTFT, TPOT, and e2e latency
  histograms plus per-request trace lanes;
- scheduler work — ``on_prefill_chunk`` / ``on_decode_step`` /
  ``on_admission_wait`` / ``on_prefix_hits`` / ``on_eviction`` /
  ``on_pool`` mirror the :class:`repro.serve.scheduler.ServeStats`
  counters as live Prometheus series;
- PIM depth — ``on_pim_totals`` accumulates the exact-path work totals
  collected by ``repro.models.layers.collect_pim_stats`` inside the
  jitted decode step (converts, speculation failures, saturations) and
  joins them with ``repro.core.energy`` into a live estimated pJ/token
  gauge (the Titanium Law's serve-time face).

Timing discipline: every timestamp is taken host-side at points where
the engine already synced (its one ``jax.device_get`` per iteration), so
telemetry adds **no** device syncs; greedy outputs are bit-identical
with telemetry on or off (tested). Eviction-by-recompute replays a
request from scratch — its replay re-observes queue-wait/TTFT (each
observation is one *scheduling attempt*), while ``requests_completed``
counts the request once.

``jax.profiler`` hooks: with ``profile_dir`` set, ``profile()`` wraps a
run in ``start_trace``/``stop_trace`` and ``annotate_step`` marks each
jitted decode/prefill dispatch with a ``StepTraceAnnotation`` so device
profiles line up with scheduler iterations. Both are inert when
``profile_dir`` is ``None``.
"""

from __future__ import annotations

import contextlib

from repro.core import energy as en
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import ENGINE_TID, Tracer

# finer than the generic latency defaults at the fast end: toy-model
# decode steps on CPU land well under a millisecond
STEP_BUCKETS = (1e-5, 5e-5, 1e-4, 5e-4, 0.001, 0.0025, 0.005, 0.01,
                0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

PIM_COUNTER_HELP = {
    "adc_converts": "ADC conversions performed (speculation + recovery)",
    "no_spec_converts": "converts a no-speculation design would need",
    "spec_failures": "failed speculative (column x slice) conversions",
    "spec_attempts": "speculative conversion attempts",
    "recovery_saturations": "accepted fidelity losses (saturated recovery)",
    "cycles": "crossbar cycles consumed",
    "macs": "logical 8b MACs computed",
}


def record_pim_totals(registry: MetricsRegistry, totals: dict,
                      n_tokens: int, adc_bits: int, *,
                      engine: str = "serve") -> dict:
    """Fold one collected-totals dict into PIM counters + derived gauges.

    ``totals`` is a ``repro.models.layers.pim_stats_totals`` dict (host
    values). Returns the derived per-token dict (converts/token, failure
    rate, saturations/token, estimated pJ/token via the §2.5 component
    energies) so callers can report it inline too.
    """
    for k, help_ in PIM_COUNTER_HELP.items():
        registry.counter(f"repro_pim_{k}_total", help_,
                         ("engine",)).inc(int(totals.get(k, 0)),
                                          engine=engine)
    registry.counter("repro_pim_decode_tokens_total",
                     "useful decode tokens the PIM counters cover",
                     ("engine",)).inc(n_tokens, engine=engine)
    c = registry  # re-read accumulated series for the derived gauges
    tok = c.counter("repro_pim_decode_tokens_total", "",
                    ("engine",)).get(engine=engine)
    tot = {k: c.counter(f"repro_pim_{k}_total", "", ("engine",))
           .get(engine=engine) for k in PIM_COUNTER_HELP}
    energy = en.pim_work_energy_pj(tot, adc_bits)
    derived = {
        "adc_converts_per_token": tot["adc_converts"] / max(tok, 1),
        "no_spec_converts_per_token":
            tot["no_spec_converts"] / max(tok, 1),
        "spec_failure_rate":
            tot["spec_failures"] / max(tot["spec_attempts"], 1),
        "saturations_per_token":
            tot["recovery_saturations"] / max(tok, 1),
        "pj_per_token": energy["total_pj"] / max(tok, 1),
        "adc_pj_per_token": energy["e_adc_pj"] / max(tok, 1),
    }
    for k, v in derived.items():
        registry.gauge(f"repro_pim_{k}",
                       f"running per-token {k.replace('_', ' ')} over the "
                       f"collected decode steps",
                       ("engine",)).set(v, engine=engine)
    return derived


class ServeTelemetry:
    """Live telemetry for one serve-engine run. See the module docstring
    for the hook taxonomy; every hook is a no-op on
    :data:`NULL_TELEMETRY` (the engines' default)."""

    enabled = True

    def __init__(self, engine: str = "serve", *,
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None, tracing: bool = False,
                 profile_dir: str | None = None,
                 pim_stats: bool = True):
        self.engine = engine
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None \
            else Tracer(enabled=tracing)
        self.profile_dir = profile_dir
        self.pim_stats = pim_stats
        self.pim_adc_bits: int | None = None
        self._submit_ts: dict[int, float] = {}
        self._restart_ts: dict[int, float] = {}
        self._last_token_ts: dict[int, float] = {}
        self._lab = {"engine": engine}
        r = self.registry
        self._submitted = r.counter(
            "repro_serve_requests_submitted_total",
            "requests entered through submit()", ("engine",))
        self._completed = r.counter(
            "repro_serve_requests_completed_total",
            "requests retired, by finish reason", ("engine", "reason"))
        self._tokens = r.counter(
            "repro_serve_tokens_generated_total",
            "tokens committed (first tokens + decode tokens)", ("engine",))
        self._decode_steps = r.counter(
            "repro_serve_decode_steps_total",
            "batched decode_step dispatches", ("engine",))
        self._slot_tokens = r.counter(
            "repro_serve_decode_slot_tokens_total",
            "useful (non-padding) tokens over all decode steps",
            ("engine",))
        self._prefill_chunks = r.counter(
            "repro_serve_prefill_chunks_total",
            "prefill chunk dispatches", ("engine",))
        self._prefill_tokens = r.counter(
            "repro_serve_prefill_tokens_total",
            "prompt tokens prefilled (recompute included)", ("engine",))
        self._waits = r.counter(
            "repro_serve_admission_waits_total",
            "iterations the queue head waited for pool blocks", ("engine",))
        self._evictions = r.counter(
            "repro_serve_evictions_total",
            "preempt-by-recompute events", ("engine",))
        self._prefix_hits = r.counter(
            "repro_serve_prefix_block_hits_total",
            "shared-prefix KV blocks reused at admission", ("engine",))
        self._blocks = r.gauge(
            "repro_serve_blocks_in_use", "KV pool occupancy (blocks)",
            ("engine",))
        self._peak_blocks = r.gauge(
            "repro_serve_peak_blocks_in_use",
            "max KV pool occupancy seen (blocks)", ("engine",))
        self._queue_wait = r.histogram(
            "repro_serve_queue_wait_seconds",
            "submit (or eviction) to slot admission", ("engine",))
        self._ttft = r.histogram(
            "repro_serve_ttft_seconds",
            "submit (or eviction) to first committed token", ("engine",))
        self._tpot = r.histogram(
            "repro_serve_tpot_seconds",
            "inter-token latency during decode", ("engine",),
            buckets=STEP_BUCKETS)
        self._e2e = r.histogram(
            "repro_serve_e2e_seconds", "submit to request completion",
            ("engine",))
        self._step_time = r.histogram(
            "repro_serve_decode_step_seconds",
            "host wall time of one batched decode step (dispatch + the "
            "iteration's one device_get)", ("engine",),
            buckets=STEP_BUCKETS)
        self.tracer.name_track(ENGINE_TID, f"{engine} engine")

    # ------------------------------------------------------------ helpers
    def _now_s(self) -> float:
        return self.tracer.now() / 1e6

    def _req_tid(self, uid: int) -> int:
        return uid + 1

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    # --------------------------------------------------------- lifecycle
    def on_submit(self, uid: int) -> None:
        self._submitted.inc(**self._lab)
        self._submit_ts[uid] = self._now_s()
        self.tracer.name_track(self._req_tid(uid), f"request {uid}")
        self.tracer.instant("submit", tid=self._req_tid(uid), uid=uid)

    def on_admit(self, uid: int, prompt_len: int) -> None:
        now = self._now_s()
        t0 = self._restart_ts.get(uid, self._submit_ts.get(uid, now))
        self._queue_wait.observe(now - t0, **self._lab)
        self.tracer.complete("queue_wait", t0 * 1e6, (now - t0) * 1e6,
                             tid=self._req_tid(uid), uid=uid)
        self.tracer.instant("admit", tid=self._req_tid(uid), uid=uid,
                            prompt_len=prompt_len)

    def on_prefill_chunk(self, uid: int, lo: int, hi: int) -> None:
        self._prefill_chunks.inc(**self._lab)
        self._prefill_tokens.inc(hi - lo, **self._lab)

    def on_prefix_hits(self, uid: int, n_blocks: int) -> None:
        if n_blocks:
            self._prefix_hits.inc(n_blocks, **self._lab)
            self.tracer.instant("prefix_hit", tid=self._req_tid(uid),
                                uid=uid, blocks=n_blocks)

    def on_admission_wait(self, uid: int) -> None:
        self._waits.inc(**self._lab)

    def on_eviction(self, uid: int) -> None:
        self._evictions.inc(**self._lab)
        now = self._now_s()
        self._restart_ts[uid] = now
        self._last_token_ts.pop(uid, None)
        self.tracer.instant("evicted", tid=self._req_tid(uid), uid=uid)

    def on_pool(self, blocks_in_use: int, peak: int) -> None:
        self._blocks.set(blocks_in_use, **self._lab)
        self._peak_blocks.set(peak, **self._lab)

    def on_decode_step(self, n_live: int) -> None:
        self._decode_steps.inc(**self._lab)
        self._slot_tokens.inc(n_live, **self._lab)

    def observe_decode_step_seconds(self, dt: float) -> None:
        self._step_time.observe(dt, **self._lab)

    def on_token(self, uid: int) -> None:
        """One committed token for ``uid`` — first-vs-subsequent decides
        TTFT vs TPOT (host clock; called right after the batched
        device_get that surfaced the logits)."""
        now = self._now_s()
        self._tokens.inc(**self._lab)
        last = self._last_token_ts.get(uid)
        if last is None:
            t0 = self._restart_ts.get(uid, self._submit_ts.get(uid, now))
            self._ttft.observe(now - t0, **self._lab)
            self.tracer.instant("first_token", tid=self._req_tid(uid),
                                uid=uid)
        else:
            self._tpot.observe(now - last, **self._lab)
        self._last_token_ts[uid] = now

    def on_finish(self, uid: int, reason: str, n_tokens: int) -> None:
        now = self._now_s()
        t0 = self._submit_ts.pop(uid, now)
        self._restart_ts.pop(uid, None)
        self._last_token_ts.pop(uid, None)
        self._completed.inc(engine=self.engine, reason=reason)
        self._e2e.observe(now - t0, **self._lab)
        self.tracer.complete("request", t0 * 1e6, (now - t0) * 1e6,
                             tid=self._req_tid(uid), uid=uid,
                             reason=reason, tokens=n_tokens)

    # --------------------------------------------------------------- pim
    def wants_pim_stats(self, cfg) -> bool:
        """Exact mode is the only path with work counters to collect."""
        return bool(self.pim_stats) and cfg.pim_mode == "exact"

    def on_pim_totals(self, totals: dict, n_tokens: int) -> dict:
        bits = self.pim_adc_bits if self.pim_adc_bits is not None else 8
        return record_pim_totals(self.registry, totals, n_tokens, bits,
                                 engine=self.engine)

    def record_stats(self, stats) -> None:
        """Mirror a final ``ServeStats.snapshot()`` as gauges (one call at
        export time — the per-event counters above track the live run)."""
        for k, v in stats.snapshot().items():
            self.registry.gauge(
                f"repro_serve_stats_{k}",
                f"ServeStats.{k} at export time", ("engine",)).set(
                    float(v), **self._lab)

    # --------------------------------------------------------- profiling
    def annotate_step(self, name: str, step: int):
        """``jax.profiler.StepTraceAnnotation`` around a jitted dispatch
        when device profiling is configured; inert otherwise."""
        if self.profile_dir is None:
            return contextlib.nullcontext()
        import jax.profiler
        return jax.profiler.StepTraceAnnotation(name, step_num=step)

    @contextlib.contextmanager
    def profile(self):
        """Wrap a run in ``jax.profiler.start_trace``/``stop_trace`` when
        ``profile_dir`` is set (serve ``--profile-dir``)."""
        if self.profile_dir is None:
            yield
            return
        import jax.profiler
        jax.profiler.start_trace(self.profile_dir)
        try:
            yield
        finally:
            jax.profiler.stop_trace()


class _NullTelemetry(ServeTelemetry):
    """Hook-compatible no-op: every metric write hits the disabled
    registry's shared null metric and every span is a disabled-tracer
    pass-through, so engines call hooks unconditionally."""

    enabled = False

    def __init__(self):
        super().__init__("null", registry=MetricsRegistry(enabled=False),
                         tracer=Tracer(enabled=False), pim_stats=False)

    def on_submit(self, uid):
        pass

    def on_admit(self, uid, prompt_len):
        pass

    def on_token(self, uid):
        pass

    def on_finish(self, uid, reason, n_tokens):
        pass

    def observe_decode_step_seconds(self, dt):
        pass


NULL_TELEMETRY = _NullTelemetry()
