"""Dynamic Input Slicing: speculation + recovery (paper §4.3).

Speculation processes inputs with an aggressive slicing (default 4b-2b-2b:
three cycles, three ADC converts per column). Any conversion that saturates
at the ADC bounds is flagged; the failed (column x input-slice) results are
*replaced* by a conservative recovery pass that re-slices that input slice
into 1b sub-slices. The crossbar always runs all recovery cycles (11 cycles
total for 3+8), but ADCs only convert — i.e. only *count work* — for columns
that failed speculation. If recovery itself saturates (rare) the saturated
value is accepted and propagated (paper §3.4).

At noise 0 the whole speculate/recover pass runs as ONE fused kernel op
(``repro.kernels.ops.fused_spec_crossbar_forward``: in-kernel spec-slice
cropping, per-segment ADC clamp, failure detection, 1b recovery converts,
select, shift+add, center term) — bit-exact vs the Python loop below,
which remains the oracle (``backend='python'``) and the noisy path.
Recovery-convert counts are derived *analytically* from the per-spec-slice
failure counts the kernel returns: ``converts = attempts + sum_i width_i *
failures_i`` — exactly what the loop accumulates.

The functional result is bit-exact with hardware; ADC-convert counts are the
quantity the Titanium Law energy model consumes. Counters that are pure
shape arithmetic (attempts, the no-speculation baseline, MACs) are exact
Python ints — at production batch x column x slice scales they overflow
int32 (the historical dtype); data-dependent counters accumulate in
``crossbar.work_dtype()`` (int64 under ``jax_enable_x64``, else int32).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import slicing as sl

SPEC_SLICING = (4, 2, 2)  # paper: three speculative slices of 2-4 bits
RECOVERY_BITS = 1         # paper: eight 1b recovery slices


@dataclasses.dataclass
class SpeculationStats:
    adc_converts: jnp.ndarray          # converts actually performed (spec + recovery)
    no_spec_converts: int              # converts a recovery-only design would need
    spec_failures: jnp.ndarray         # failed (column x spec-slice) conversions
    spec_attempts: int
    recovery_saturations: jnp.ndarray  # accepted fidelity losses
    cycles: int                        # crossbar cycles consumed (3 spec + 8 rec = 11)
    macs: int

    @property
    def failure_rate(self):
        return self.spec_failures / jnp.maximum(self.spec_attempts, 1)


def forward(x_u8: jnp.ndarray,
            enc: co.EncodedWeights,
            spec_slicing: Sequence[int] = SPEC_SLICING,
            adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
            *,
            noise_level: float = 0.0,
            key: jax.Array | None = None,
            backend: str | None = None,
            valid: jnp.ndarray | None = None
            ) -> tuple[jnp.ndarray, SpeculationStats]:
    """Speculative crossbar forward. x_u8: (B, rows) -> (psum (B, cols), stats).

    ``backend`` selects the kernel backend for the noiseless fused path
    per the ``repro.kernels.ops`` registry rules ('xla' / 'interpret' /
    'pallas-tpu' / 'auto', env-overridable); ``backend='python'`` forces
    the reference loop below (the oracle the differential tests compare
    against). Noisy runs always use the loop and require a ``key``.

    ``valid`` optionally masks padded slice planes (PR 4's ragged
    per-site plans): masked planes are zeroed — numerically inert under
    a zero-preserving ADC — but still counted by the work stats on both
    paths; convert/cycle accounting is only meaningful for unpadded
    encodings.
    """
    if noise_level and key is None:
        raise ValueError(
            f"noise_level={noise_level} requires a PRNG key: pass key= "
            "(silently running noiseless would drop the requested noise)")
    adc_lib.check_zero_preserving(adc)  # the padding contract
    B = x_u8.shape[0]
    n_seg, R = enc.n_segments, enc.rows_per_xbar
    planes = jnp.asarray(enc.planes)
    if valid is not None:
        planes = planes * valid[:, None, None, None].astype(planes.dtype)
    spec_bounds = sl.slice_bounds(spec_slicing, sl.INPUT_BITS)
    wd = xbar.work_dtype()

    # shape-static work counters: exact Python ints (immune to int32
    # overflow at production batch x column x slice scales)
    n_cols = B * n_seg * enc.cols
    attempts = n_cols * len(spec_bounds) * enc.n_slices
    no_spec = n_cols * sl.INPUT_BITS * enc.n_slices
    cycles = len(spec_slicing) + sl.INPUT_BITS
    macs = B * enc.rows * enc.cols

    if noise_level == 0.0 and backend != "python":
        from repro.kernels import ops as kops
        psum, fails, rec_sats = kops.fused_spec_crossbar_forward(
            x_u8, planes, enc.shifts, jnp.asarray(enc.centers),
            spec_slicing=tuple(int(b) for b in spec_slicing),
            adc_lo=adc.lo, adc_hi=adc.hi, rows_per_xbar=R,
            backend=backend)
        widths = jnp.asarray([hi - lo + 1 for (hi, lo) in spec_bounds], wd)
        fails = fails.astype(wd)
        stats = SpeculationStats(
            adc_converts=attempts + (widths * fails).sum(),
            no_spec_converts=no_spec,
            spec_failures=fails.sum(),
            spec_attempts=attempts,
            recovery_saturations=rec_sats.astype(wd),
            cycles=cycles, macs=macs)
        return psum, stats

    xs = xbar._segment_inputs(x_u8, n_seg, R)
    psum = co.center_term(x_u8, enc)
    rec_converts = jnp.zeros((), wd)   # recovery converts actually billed
    failures = jnp.zeros((), wd)
    rec_sats = jnp.zeros((), wd)

    n_keys = sum(1 + w for w in spec_slicing) * enc.n_slices
    keys = (jax.random.split(key, n_keys) if key is not None else [None] * n_keys)
    ki = 0
    for (hi, li) in spec_bounds:
        width = hi - li + 1
        x_spec = sl.crop_unsigned(xs, hi, li)  # (B, n_seg, R)
        for j in range(enc.n_slices):
            lw = enc.shifts[j]
            pos, neg = xbar.column_sums(x_spec, planes[j])
            spec_val, spec_sat = adc_lib.convert(
                pos - neg, adc, noise_level=noise_level,
                pos_sum=pos, neg_sum=neg, key=keys[ki])
            ki += 1
            # --- recovery: re-process this input slice as `width` 1b slices.
            rec_total = jnp.zeros_like(spec_val)
            for b in range(width - 1, -1, -1):  # local bit positions
                x_bit = sl.crop_unsigned(xs, li + b, li + b)
                rpos, rneg = xbar.column_sums(x_bit, planes[j])
                rval, rsat = adc_lib.convert(
                    rpos - rneg, adc, noise_level=noise_level,
                    pos_sum=rpos, neg_sum=rneg, key=keys[ki])
                ki += 1
                rec_total = rec_total + (rval << b)
                rec_sats = rec_sats + (rsat & spec_sat).sum(dtype=wd)
            value = jnp.where(spec_sat, rec_total, spec_val)
            psum = psum + (value.sum(axis=1) << (li + lw))
            # work accounting (per paper: recovery ADCs power-gated on success)
            failures = failures + spec_sat.sum(dtype=wd)
            rec_converts = rec_converts + width * spec_sat.sum(dtype=wd)
    stats = SpeculationStats(
        adc_converts=attempts + rec_converts,
        no_spec_converts=no_spec,
        spec_failures=failures,
        spec_attempts=attempts,
        recovery_saturations=rec_sats,
        cycles=cycles,
        macs=macs)
    return psum, stats
