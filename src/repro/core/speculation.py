"""Dynamic Input Slicing: speculation + recovery (paper §4.3).

Speculation processes inputs with an aggressive slicing (default 4b-2b-2b:
three cycles, three ADC converts per column). Any conversion that saturates
at the ADC bounds is flagged; the failed (column x input-slice) results are
*replaced* by a conservative recovery pass that re-slices that input slice
into 1b sub-slices. The crossbar always runs all recovery cycles (11 cycles
total for 3+8), but ADCs only convert — i.e. only *count work* — for columns
that failed speculation. If recovery itself saturates (rare) the saturated
value is accepted and propagated (paper §3.4).

The functional result is bit-exact with hardware; ADC-convert counts are the
quantity the Titanium Law energy model consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import slicing as sl

SPEC_SLICING = (4, 2, 2)  # paper: three speculative slices of 2-4 bits
RECOVERY_BITS = 1         # paper: eight 1b recovery slices


@dataclasses.dataclass
class SpeculationStats:
    adc_converts: jnp.ndarray          # converts actually performed (spec + recovery)
    no_spec_converts: jnp.ndarray      # converts a recovery-only design would need
    spec_failures: jnp.ndarray         # failed (column x spec-slice) conversions
    spec_attempts: jnp.ndarray
    recovery_saturations: jnp.ndarray  # accepted fidelity losses
    cycles: int                        # crossbar cycles consumed (3 spec + 8 rec = 11)
    macs: int

    @property
    def failure_rate(self):
        return self.spec_failures / jnp.maximum(self.spec_attempts, 1)


def forward(x_u8: jnp.ndarray,
            enc: co.EncodedWeights,
            spec_slicing: Sequence[int] = SPEC_SLICING,
            adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
            *,
            noise_level: float = 0.0,
            key: jax.Array | None = None) -> tuple[jnp.ndarray, SpeculationStats]:
    """Speculative crossbar forward. x_u8: (B, rows) -> (psum (B, cols), stats).

    Padded slice planes (see ``crossbar.forward``) are numerically inert
    but still counted by the work stats — convert/cycle accounting is only
    meaningful for unpadded encodings.
    """
    B = x_u8.shape[0]
    n_seg, R = enc.n_segments, enc.rows_per_xbar
    xs = xbar._segment_inputs(x_u8, n_seg, R)
    planes = jnp.asarray(enc.planes)
    spec_bounds = sl.slice_bounds(spec_slicing, sl.INPUT_BITS)

    psum = co.center_term(x_u8, enc)
    converts = jnp.zeros((), jnp.int32)
    failures = jnp.zeros((), jnp.int32)
    attempts = jnp.zeros((), jnp.int32)
    rec_sats = jnp.zeros((), jnp.int32)

    n_keys = sum(1 + w for w in spec_slicing) * enc.n_slices
    keys = (jax.random.split(key, n_keys) if key is not None else [None] * n_keys)
    ki = 0
    for (hi, li) in spec_bounds:
        width = hi - li + 1
        x_spec = sl.crop_unsigned(xs, hi, li)  # (B, n_seg, R)
        for j in range(enc.n_slices):
            lw = enc.shifts[j]
            pos, neg = xbar.column_sums(x_spec, planes[j])
            spec_val, spec_sat = adc_lib.convert(
                pos - neg, adc, noise_level=noise_level,
                pos_sum=pos, neg_sum=neg, key=keys[ki])
            ki += 1
            # --- recovery: re-process this input slice as `width` 1b slices.
            rec_total = jnp.zeros_like(spec_val)
            for b in range(width - 1, -1, -1):  # local bit positions
                x_bit = sl.crop_unsigned(xs, li + b, li + b)
                rpos, rneg = xbar.column_sums(x_bit, planes[j])
                rval, rsat = adc_lib.convert(
                    rpos - rneg, adc, noise_level=noise_level,
                    pos_sum=rpos, neg_sum=rneg, key=keys[ki])
                ki += 1
                rec_total = rec_total + (rval << b)
                rec_sats = rec_sats + (rsat & spec_sat).sum()
            value = jnp.where(spec_sat, rec_total, spec_val)
            psum = psum + (value.sum(axis=1) << (li + lw))
            # work accounting (per paper: recovery ADCs power-gated on success)
            n_cols = B * n_seg * enc.cols
            attempts = attempts + n_cols
            failures = failures + spec_sat.sum()
            converts = converts + n_cols + width * spec_sat.sum()
    stats = SpeculationStats(
        adc_converts=converts,
        no_spec_converts=jnp.asarray(
            B * n_seg * enc.cols * sl.INPUT_BITS * enc.n_slices, jnp.int32),
        spec_failures=failures,
        spec_attempts=attempts,
        recovery_saturations=rec_sats,
        cycles=len(spec_slicing) + sl.INPUT_BITS,
        macs=B * enc.rows * enc.cols)
    return psum, stats
