"""RaellaLinear: a DNN linear layer executed with RAELLA's arithmetic.

Three execution modes:

  exact — bit-exact functional simulation of the accelerator datapath
          (Center+Offset, sliced crossbars, 7b ADC, optional speculation,
          optional analog noise). Used for the paper's accuracy/fidelity
          experiments. Signed inputs run as two unsigned passes (paper §5.1).

  fast  — the TPU-native transfer of the paper's insight: Center+Offset is
          per-output-channel zero-point quantization, so the layer runs as an
          int8 MXU matmul on the *offsets* plus a digital rank-1 center term
          phi * sum(x) (Eq. 1). Backed by the Pallas kernel in
          repro.kernels.int8_matmul (XLA fallback with identical numerics).

  off   — plain float matmul (baseline / training path).

Preprocessing (= the paper's compile step, Algorithm 1) happens once in
``prepare``; the returned plan is reused for any number of inferences,
mirroring ReRAM's write-once/read-many amortization.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adc as adc_lib
from repro.core import backends as bk
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import slicing as sl
from repro.core import speculation as spec
from repro.quant import quantize as q


@dataclasses.dataclass
class PimPlan:
    """Compile-time artifact for one layer (paper: programmed crossbar state)."""
    enc: co.EncodedWeights          # Center+Offset encoded weight slices
    lq: q.LayerQuant                # quantization parameters
    w_q: np.ndarray                 # int8 weights (rows, cols) — reference path
    weight_slicing: tuple[int, ...] | None  # None: per-site (enc carries shifts)
    adc: adc_lib.ADCConfig
    speculation: bool
    spec_slicing: tuple[int, ...] = spec.SPEC_SLICING
    encode_mode: str = "center"     # "center" | "zero" (differential baseline)
    # kernel backend for the static-slicing exact path and the fast path
    # (repro.kernels.ops registry: 'auto' | 'xla' | 'interpret' |
    # 'pallas-tpu' | ...; 'python' forces the crossbar reference loop).
    # None defers to the call site / 'auto'.
    kernel_backend: str | None = None
    # analog array model for the exact path (repro.core.backends): None /
    # IdealSim = exact integer read (fused-kernel eligible); NonidealSim =
    # a ReRAM die with program noise / drift / stuck-ats / IR drop. A
    # nonideal device forces static input slicing — speculation's
    # recovery rule assumes an ideal saturation signal, so modelling it
    # on a faulty die is future work (ROADMAP).
    device: bk.CrossbarBackend | None = None
    # fast (TPU-native) path: asymmetric centered quantization, Eq. 1 in float
    fast_w_off: np.ndarray | None = None    # int8 offsets (rows, cols)
    fast_centers: np.ndarray | None = None  # int32 per-column centers
    fast_scale: np.ndarray | None = None    # fp32 per-column scale

    @property
    def w_u(self) -> np.ndarray:
        return np.asarray(self.w_q, np.int64) + 128


def prepare(w: jnp.ndarray,
            x_cal: jnp.ndarray,
            *,
            weight_slicing: Sequence[int] = (4, 2, 2),
            adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
            speculation: bool = True,
            encode_mode: str = "center",
            bias: jnp.ndarray | None = None,
            relu_out: bool = False,
            signed_inputs: bool | None = None) -> PimPlan:
    """Quantize + Center+Offset encode + slice a layer's weights.

    ``signed_inputs=None`` infers signedness from ``x_cal`` (requires
    concrete values); the model compile step passes ``True`` explicitly —
    transformer residual-stream activations are always signed.
    """
    lq, w_q = q.calibrate_layer(w, x_cal, bias=bias, relu_out=relu_out,
                                signed_inputs=signed_inputs)
    w_u = np.asarray(w_q, np.int64) + 128
    enc = co.encode(w_u, weight_slicing, mode=encode_mode)
    w_off, centers, fscale = q.quantize_weights_centered(w)
    return PimPlan(enc=enc, lq=lq, w_q=np.asarray(w_q),
                   weight_slicing=tuple(weight_slicing), adc=adc,
                   speculation=speculation, encode_mode=encode_mode,
                   fast_w_off=np.asarray(w_off), fast_centers=np.asarray(centers),
                   fast_scale=np.asarray(fscale))


def _unsigned_passes(x_q: jnp.ndarray, signed: bool) -> list[tuple[int, jnp.ndarray]]:
    """Signed inputs -> (sign, unsigned codes) passes; unsigned -> single pass."""
    if not signed:
        return [(1, x_q)]
    return [(1, jnp.maximum(x_q, 0)), (-1, jnp.maximum(-x_q, 0))]


def _accumulate_int(x_q: jnp.ndarray, plan: PimPlan, *,
                    input_slicing: Sequence[int] | None,
                    noise_level: float, key) -> tuple[jnp.ndarray, list]:
    """x_q (B, rows) int codes -> x_q @ w_q int32 via the crossbar sim."""
    stats = []
    acc = jnp.zeros((x_q.shape[0], plan.enc.cols), jnp.int32)
    passes = _unsigned_passes(x_q, plan.lq.x_signed)
    nonideal = isinstance(plan.device, bk.NonidealSim)
    for i, (sign, xp) in enumerate(passes):
        k = None if key is None else jax.random.fold_in(key, i)
        if plan.speculation and not nonideal:
            # noiseless: the fused speculate/recover kernel (the failure
            # mask prices recovery converts analytically); noisy: the
            # Python loop (the per-conversion noise model is stateful)
            psum, st = spec.forward(xp, plan.enc, plan.spec_slicing, plan.adc,
                                    noise_level=noise_level, key=k,
                                    backend=plan.kernel_backend)
        else:
            in_sl = (1,) * sl.INPUT_BITS if input_slicing is None \
                else input_slicing
            psum, st = xbar.forward(xp, plan.enc, in_sl, plan.adc,
                                    noise_level=noise_level, key=k,
                                    backend=plan.kernel_backend,
                                    device=plan.device)
        acc = acc + sign * psum
        stats.append(st)
    # unsigned-weight-domain -> signed int8 weight domain: w_q = w_u - 128
    x_sum = x_q.astype(jnp.int32).sum(axis=-1, keepdims=True)
    acc = acc - 128 * x_sum
    return acc, stats


def forward_exact(x: jnp.ndarray, plan: PimPlan, *,
                  input_slicing: Sequence[int] | None = None,
                  noise_level: float = 0.0,
                  key: jax.Array | None = None,
                  return_stats: bool = False):
    """Float-in / float-out exact accelerator simulation.

    ``plan.device`` picks the analog array model (``core.backends``): a
    ``NonidealSim`` die reads through its programmed nonidealities (and
    forces static input slicing — see ``PimPlan.device``); the default
    ideal device keeps the historical bit-exact datapath.
    """
    if plan.lq.x_signed:
        x_q = jnp.clip(jnp.round(x / plan.lq.x_scale), -127, 127).astype(jnp.int32)
    else:
        x_q = jnp.clip(jnp.round(x / plan.lq.x_scale), 0, 255).astype(jnp.int32)
    y_int, stats = _accumulate_int(x_q, plan, input_slicing=input_slicing,
                                   noise_level=noise_level, key=key)
    w_col_sum = jnp.asarray(plan.w_q.astype(np.int32).sum(axis=0))
    y = q.dequantize(y_int, plan.lq, x_q.sum(-1), w_col_sum)
    if return_stats:
        return y, stats
    return y


def forward_int_reference(x: jnp.ndarray, plan: PimPlan) -> jnp.ndarray:
    """Ideal 8b-quantized layer (no fidelity loss) — the paper's 'expected'."""
    if plan.lq.x_signed:
        x_q = jnp.clip(jnp.round(x / plan.lq.x_scale), -127, 127).astype(jnp.int32)
    else:
        x_q = jnp.clip(jnp.round(x / plan.lq.x_scale), 0, 255).astype(jnp.int32)
    y_int = jnp.einsum("br,rc->bc", x_q, jnp.asarray(plan.w_q, jnp.int32),
                       preferred_element_type=jnp.int32)
    w_col_sum = jnp.asarray(plan.w_q.astype(np.int32).sum(axis=0))
    return q.dequantize(y_int, plan.lq, x_q.sum(-1), w_col_sum)


def forward_fast(x: jnp.ndarray, plan: PimPlan, *, use_pallas: bool = False,
                 backend: str | None = None) -> jnp.ndarray:
    """TPU-native centered-int8 path (no ADC model — deployment arithmetic).

    Implements Eq. 1 in the quantized-float domain:
        y = s_x * s_w ⊙ ( x_q @ W_off  +  sum(x_q) ⊗ phi )
    where (W_off, phi, s_w) come from asymmetric per-channel centered
    quantization — offsets guaranteed int8, centers digital.

    ``backend`` (or ``plan.kernel_backend``) selects a registry backend
    by name; otherwise the legacy ``use_pallas`` flag applies.
    """
    from repro.kernels import ops as kops
    if plan.lq.x_signed:
        x_q = jnp.clip(jnp.round(x / plan.lq.x_scale), -127, 127).astype(jnp.int8)
        shift = 0
    else:
        # shift unsigned codes to the signed domain: u - 128 in [-128, 127]
        x_q = (jnp.clip(jnp.round(x / plan.lq.x_scale), 0, 255) - 128).astype(jnp.int8)
        shift = 128
    be = backend or plan.kernel_backend
    if be is not None and be not in ("auto", "python"):
        y_int = kops.centered_int8_matmul(
            x_q, jnp.asarray(plan.fast_w_off), jnp.asarray(plan.fast_centers),
            backend=be)
    else:
        y_int = kops.centered_int8_matmul(
            x_q, jnp.asarray(plan.fast_w_off), jnp.asarray(plan.fast_centers),
            use_pallas=use_pallas)
    if shift:
        # undo the input shift: u @ W = (u-128) @ W + 128 * colsum(W_off + phi)
        w_col = (plan.fast_w_off.astype(np.int64).sum(axis=0)
                 + plan.fast_w_off.shape[0] * plan.fast_centers.astype(np.int64))
        y_int = y_int + shift * jnp.asarray(w_col, jnp.int32)[None, :]
    y = plan.fast_scale[None, :] * plan.lq.x_scale * y_int.astype(jnp.float32)
    if plan.lq.bias is not None:
        y = y + plan.lq.bias[None, :]
    return y


def output_codes(y: jnp.ndarray, plan: PimPlan, relu: bool = False) -> jnp.ndarray:
    """8b requantized output codes (what flows between PIM tiles)."""
    return q.requantize_outputs(y, plan.lq, relu=relu)
