"""The paper's seven evaluation DNNs as layer-shape tables (§6.2).

Conv layers are (k*k*Cin)-row filters with H_out*W_out output positions;
FC/projection layers are d_in-row filters with one position per token.
GoogLeNet / InceptionV3 / ShuffleNetV2 branch structures are lightly
approximated (stated in DESIGN.md); ResNet / MobileNetV2 / BERT tables are
exact. Ratios RAELLA/ISAAC depend on filter-length and signedness
distributions, which these tables carry faithfully.
"""

from __future__ import annotations

from repro.core.mapping import LayerShape


def _conv(name, cin, cout, k, hw, stride=1, signed=False, depthwise=False,
          last=False) -> LayerShape:
    out_hw = hw // stride
    flen = (k * k) if depthwise else (k * k * cin)
    return LayerShape(name=name, filter_len=flen, n_filters=cout,
                      n_positions=out_hw * out_hw, signed_inputs=signed,
                      depthwise=depthwise, last_layer=last,
                      row_positions=out_hw)


def _fc(name, din, dout, tokens=1, signed=False, last=False) -> LayerShape:
    return LayerShape(name=name, filter_len=din, n_filters=dout,
                      n_positions=tokens, signed_inputs=signed, last_layer=last,
                      row_positions=tokens)


def resnet18() -> list[LayerShape]:
    ls = [_conv("conv1", 3, 64, 7, 224, 2)]
    cfg = [(64, 64, 2, 1, 56), (64, 128, 2, 2, 56), (128, 256, 2, 2, 28),
           (256, 512, 2, 2, 14)]
    for cin, cout, blocks, stride, hw in cfg:
        for b in range(blocks):
            s = stride if b == 0 else 1
            c_in = cin if b == 0 else cout
            ls.append(_conv(f"l{cout}b{b}c1", c_in, cout, 3, hw, s))
            ls.append(_conv(f"l{cout}b{b}c2", cout, cout, 3, hw // stride, 1))
            if b == 0 and (s != 1 or c_in != cout):
                ls.append(_conv(f"l{cout}down", c_in, cout, 1, hw, s))
    ls.append(_fc("fc", 512, 1000, last=True))
    return ls


def resnet50() -> list[LayerShape]:
    ls = [_conv("conv1", 3, 64, 7, 224, 2)]
    cfg = [(64, 256, 3, 1, 56), (256, 512, 4, 2, 56), (512, 1024, 6, 2, 28),
           (1024, 2048, 3, 2, 14)]
    for cin, cout, blocks, stride, hw in cfg:
        mid = cout // 4
        for b in range(blocks):
            s = stride if b == 0 else 1
            c_in = cin if b == 0 else cout
            ohw = hw // stride if b > 0 else hw
            ls.append(_conv(f"l{cout}b{b}c1", c_in, mid, 1, ohw if b else hw, 1))
            ls.append(_conv(f"l{cout}b{b}c2", mid, mid, 3, ohw if b else hw, s))
            ls.append(_conv(f"l{cout}b{b}c3", mid, cout, 1, ohw, 1))
            if b == 0:
                ls.append(_conv(f"l{cout}down", c_in, cout, 1, hw, s))
    ls.append(_fc("fc", 2048, 1000, last=True))
    return ls


def mobilenet_v2() -> list[LayerShape]:
    ls = [_conv("conv1", 3, 32, 3, 224, 2)]
    cin, hw = 32, 112
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, c, n, s in cfg:
        for b in range(n):
            stride = s if b == 0 else 1
            mid = cin * t
            if t != 1:
                ls.append(_conv(f"ir{c}b{b}exp", cin, mid, 1, hw, 1))
            ls.append(_conv(f"ir{c}b{b}dw", mid, mid, 3, hw, stride,
                            depthwise=True))
            hw = hw // stride
            ls.append(_conv(f"ir{c}b{b}proj", mid, c, 1, hw, 1))
            cin = c
    ls.append(_conv("conv_last", 320, 1280, 1, 7, 1))
    ls.append(_fc("fc", 1280, 1000, last=True))
    return ls


def shufflenet_v2() -> list[LayerShape]:
    ls = [_conv("conv1", 3, 24, 3, 224, 2)]
    hw, cin = 56, 24
    for cout, n in [(116, 4), (232, 8), (464, 4)]:
        for b in range(n):
            stride = 2 if b == 0 else 1
            half = cout // 2
            c_in = cin if b == 0 else half
            ls.append(_conv(f"s{cout}b{b}p1", c_in, half, 1, hw, 1))
            ls.append(_conv(f"s{cout}b{b}dw", half, half, 3, hw, stride,
                            depthwise=True))
            if b == 0:
                hw = hw // 2
            ls.append(_conv(f"s{cout}b{b}p2", half, half, 1, hw, 1))
            cin = cout
    ls.append(_conv("conv5", 464, 1024, 1, 7, 1))
    ls.append(_fc("fc", 1024, 1000, last=True))
    return ls


def googlenet() -> list[LayerShape]:
    ls = [_conv("conv1", 3, 64, 7, 224, 2),
          _conv("conv2", 64, 64, 1, 56, 1),
          _conv("conv3", 64, 192, 3, 56, 1)]
    # inception (in, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj, hw)
    inc = [("3a", 192, 64, 96, 128, 16, 32, 32, 28),
           ("3b", 256, 128, 128, 192, 32, 96, 64, 28),
           ("4a", 480, 192, 96, 208, 16, 48, 64, 14),
           ("4b", 512, 160, 112, 224, 24, 64, 64, 14),
           ("4c", 512, 128, 128, 256, 24, 64, 64, 14),
           ("4d", 512, 112, 144, 288, 32, 64, 64, 14),
           ("4e", 528, 256, 160, 320, 32, 128, 128, 14),
           ("5a", 832, 256, 160, 320, 32, 128, 128, 7),
           ("5b", 832, 384, 192, 384, 48, 128, 128, 7)]
    for n, cin, c1, c3r, c3, c5r, c5, pp, hw in inc:
        ls += [_conv(f"i{n}_1x1", cin, c1, 1, hw),
               _conv(f"i{n}_3r", cin, c3r, 1, hw),
               _conv(f"i{n}_3x3", c3r, c3, 3, hw),
               _conv(f"i{n}_5r", cin, c5r, 1, hw),
               _conv(f"i{n}_5x5", c5r, c5, 5, hw),
               _conv(f"i{n}_pp", cin, pp, 1, hw)]
    ls.append(_fc("fc", 1024, 1000, last=True))
    return ls


def inception_v3() -> list[LayerShape]:
    ls = [_conv("c1", 3, 32, 3, 299, 2), _conv("c2", 32, 32, 3, 149, 1),
          _conv("c3", 32, 64, 3, 147, 1), _conv("c4", 64, 80, 1, 73, 1),
          _conv("c5", 80, 192, 3, 71, 2)]
    for i, cin in enumerate([192, 256, 288]):  # 3x inception-A @35
        ls += [_conv(f"a{i}_1", cin, 64, 1, 35), _conv(f"a{i}_5r", cin, 48, 1, 35),
               _conv(f"a{i}_5", 48, 64, 5, 35), _conv(f"a{i}_3r", cin, 64, 1, 35),
               _conv(f"a{i}_3a", 64, 96, 3, 35), _conv(f"a{i}_3b", 96, 96, 3, 35),
               _conv(f"a{i}_pp", cin, 64 if i else 32, 1, 35)]
    ls += [_conv("redA_3", 288, 384, 3, 35, 2), _conv("redA_3r", 288, 64, 1, 35),
           _conv("redA_3a", 64, 96, 3, 35), _conv("redA_3b", 96, 96, 3, 35, 2)]
    for i, c7 in enumerate([128, 160, 160, 192]):  # 4x inception-B @17
        cin = 768
        ls += [_conv(f"b{i}_1", cin, 192, 1, 17), _conv(f"b{i}_7r", cin, c7, 1, 17),
               _conv(f"b{i}_7a", c7, c7, 3, 17), _conv(f"b{i}_7b", c7, 192, 3, 17),
               _conv(f"b{i}_dr", cin, c7, 1, 17), _conv(f"b{i}_da", c7, c7, 3, 17),
               _conv(f"b{i}_db", c7, c7, 3, 17), _conv(f"b{i}_dc", c7, c7, 3, 17),
               _conv(f"b{i}_dd", c7, 192, 3, 17), _conv(f"b{i}_pp", cin, 192, 1, 17)]
    ls += [_conv("redB_3r", 768, 192, 1, 17), _conv("redB_3", 192, 320, 3, 17, 2),
           _conv("redB_7r", 768, 192, 1, 17), _conv("redB_7a", 192, 192, 3, 17),
           _conv("redB_7b", 192, 192, 3, 17, 2)]
    for i, cin in enumerate([1280, 2048]):  # 2x inception-C @8
        ls += [_conv(f"c{i}_1", cin, 320, 1, 8), _conv(f"c{i}_3r", cin, 384, 1, 8),
               _conv(f"c{i}_3a", 384, 384, 3, 8), _conv(f"c{i}_3b", 384, 384, 3, 8),
               _conv(f"c{i}_dr", cin, 448, 1, 8), _conv(f"c{i}_da", 448, 384, 3, 8),
               _conv(f"c{i}_db", 384, 384, 3, 8), _conv(f"c{i}_pp", cin, 192, 1, 8)]
    ls.append(_fc("fc", 2048, 1000, last=True))
    return ls


def bert_large_ffn(seq: int = 384) -> list[LayerShape]:
    """Feedforward layers of BERT-Large (paper accelerates these; GELU ->
    signed inputs -> two-cycle processing)."""
    ls = []
    for i in range(24):
        ls.append(_fc(f"ffn{i}_up", 1024, 4096, tokens=seq, signed=True))
        ls.append(_fc(f"ffn{i}_down", 4096, 1024, tokens=seq, signed=True,
                      last=(i == 23)))
    return ls


WORKLOADS = {
    "resnet18": resnet18,
    "resnet50": resnet50,
    "googlenet": googlenet,
    "inception_v3": inception_v3,
    "mobilenet_v2": mobilenet_v2,
    "shufflenet_v2": shufflenet_v2,
    "bert_large": bert_large_ffn,
}
