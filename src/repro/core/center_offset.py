"""Center+Offset weight encoding (paper §4.1).

Weights live in the unsigned 8b domain [0, 255] on-crossbar (signed int8
weights are shifted by +128; the shift folds into the digital center term).
For each *filter segment* — the rows of one dot product that fit in a single
512-row crossbar (paper footnote 5) — we pick an integer center
``phi in {1..255}`` minimizing Eq. 2:

    argmin_phi  sum_j 2^{l_j} * ( sum_w D(h_j, l_j, w - phi) )^4

The residuals ``r = w - phi`` are then sign-magnitude sliced; slice values
land in ``[-(2^b - 1), 2^b - 1]`` and are programmed into the positive /
negative ReRAM of each 2T2R pair.

Implementation note: Eq. 2's inner sum depends only on the *histogram* of the
column's weight values, so we evaluate all 255 candidate centers with one
(256-bin histogram) x (255 x 256 D-table) product per slice — no per-row
work in the phi scan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import slicing as sl

ROWS_PER_CROSSBAR = 512
CENTER_CANDIDATES = np.arange(1, 256)  # paper: phi in {1..255}
COST_POWER = 4  # paper: empirically chosen power


@functools.lru_cache(maxsize=None)
def _d_table(h: int, l: int) -> np.ndarray:
    """D(h, l, w - phi) for all (phi in 1..255, w in 0..255): (255, 256) int32."""
    phi = CENTER_CANDIDATES[:, None]  # (255, 1)
    w = np.arange(256)[None, :]  # (1, 256)
    r = w - phi
    mask = (1 << (h - l + 1)) - 1
    return (np.sign(r) * ((np.abs(r) >> l) & mask)).astype(np.int32)


def column_histograms(w_u8: np.ndarray, row_mask: np.ndarray | None = None) -> np.ndarray:
    """Per-column 256-bin histograms. w_u8: (rows, cols) in [0,255] -> (cols, 256)."""
    rows, cols = w_u8.shape
    hist = np.zeros((cols, 256), dtype=np.int32)
    cidx = np.broadcast_to(np.arange(cols)[None, :], (rows, cols))
    if row_mask is not None:
        keep = np.broadcast_to(row_mask[:, None], (rows, cols))
        np.add.at(hist, (cidx[keep], w_u8[keep]), 1)
    else:
        np.add.at(hist, (cidx.ravel(), w_u8.ravel().astype(np.int64)), 1)
    return hist


def eq2_costs(hist: np.ndarray, slicing: Sequence[int]) -> np.ndarray:
    """Eq. 2 cost for every candidate center. hist: (cols, 256) -> (cols, 255)."""
    costs = np.zeros((hist.shape[0], len(CENTER_CANDIDATES)), dtype=np.float64)
    for (h, l) in sl.slice_bounds(slicing, sl.WEIGHT_BITS):
        dtab = _d_table(h, l)  # (255 phi, 256 w)
        col_sum = hist.astype(np.float64) @ dtab.T.astype(np.float64)  # (cols, 255)
        costs += (2.0 ** l) * col_sum ** COST_POWER
    return costs


def solve_centers(w_u8: np.ndarray, slicing: Sequence[int],
                  row_mask: np.ndarray | None = None) -> np.ndarray:
    """Optimal per-column center phi. w_u8: (rows<=512, cols) -> (cols,) int32."""
    hist = column_histograms(np.asarray(w_u8, dtype=np.int64), row_mask)
    costs = eq2_costs(hist, slicing)
    return CENTER_CANDIDATES[np.argmin(costs, axis=1)].astype(np.int32)


@dataclasses.dataclass(frozen=True)
class EncodedWeights:
    """A DNN layer's weights, Center+Offset encoded and sliced for crossbars.

    planes:   (n_slices, n_seg, ROWS, cols) int8 — signed sign-magnitude slice
              values in [-(2^b-1), 2^b-1]; zero-padded rows contribute nothing.
    centers:  (n_seg, cols) int32 — per filter-segment centers (unsigned domain).
    slicing:  weight slicing tuple, MSB-first.
    shifts:   per-slice recombination shift 2**l.
    rows:     true (unpadded) input length.
    """
    planes: np.ndarray
    centers: np.ndarray
    slicing: tuple[int, ...] | None
    shifts: tuple[int, ...]
    rows: int
    rows_per_xbar: int = ROWS_PER_CROSSBAR

    @property
    def n_slices(self) -> int:
        # derived from the planes (not ``len(self.slicing)``): per-site
        # compiled plans pad the slice axis to a common max, with zeroed
        # padding planes and ``shifts`` as a (possibly traced) int32 array —
        # ``slicing`` is None there (repro.models.pim_compile).
        return int(self.planes.shape[0])

    @property
    def n_segments(self) -> int:
        return self.planes.shape[1]

    @property
    def cols(self) -> int:
        return self.planes.shape[3]

    def crossbar_columns(self) -> int:
        """Physical crossbar columns consumed per filter (= n_slices)."""
        return self.n_slices


def _segment(w_u8: np.ndarray, rows_per_xbar: int) -> tuple[np.ndarray, np.ndarray]:
    """Split (rows, cols) into (n_seg, rows_per_xbar, cols) with zero pad + mask."""
    rows, cols = w_u8.shape
    n_seg = -(-rows // rows_per_xbar)
    pad = n_seg * rows_per_xbar - rows
    wp = np.pad(w_u8, ((0, pad), (0, 0)))
    mask = np.pad(np.ones(rows, dtype=bool), (0, pad))
    return (wp.reshape(n_seg, rows_per_xbar, cols),
            mask.reshape(n_seg, rows_per_xbar))


def encode(w_u8: np.ndarray, slicing: Sequence[int],
           mode: str = "center",
           rows_per_xbar: int = ROWS_PER_CROSSBAR) -> EncodedWeights:
    """Encode weights for the crossbar.

    mode='center': Center+Offset (Eq. 2 optimal centers).
    mode='zero':   Zero+Offset differential (paper's Table-4 baseline;
                   center fixed at 128 = zero in the signed domain).
    mode='unsigned': ISAAC-style raw unsigned weights (ablation baseline;
                   pair with an unsigned ADC).
    """
    w_u8 = np.asarray(w_u8, dtype=np.int64)
    if w_u8.ndim != 2:
        raise ValueError("expected (rows, cols) weight matrix")
    segs, seg_mask = _segment(w_u8, rows_per_xbar)
    n_seg, R, cols = segs.shape
    centers = np.zeros((n_seg, cols), dtype=np.int32)
    planes = np.zeros((len(slicing), n_seg, R, cols), dtype=np.int8)
    bounds = sl.slice_bounds(slicing, sl.WEIGHT_BITS)
    for s in range(n_seg):
        if mode == "center":
            centers[s] = solve_centers(segs[s], slicing, row_mask=seg_mask[s])
        elif mode == "zero":
            centers[s] = 128
        elif mode == "unsigned":
            centers[s] = 0  # ISAAC-style: raw unsigned weights, no signed 2T2R
        else:
            raise ValueError(f"unknown encode mode {mode!r}")
        r = segs[s] - centers[s][None, :]
        r = np.where(seg_mask[s][:, None], r, 0)  # padded rows -> no offsets
        for j, (h, l) in enumerate(bounds):
            mask = (1 << (h - l + 1)) - 1
            planes[j, s] = (np.sign(r) * ((np.abs(r) >> l) & mask)).astype(np.int8)
    return EncodedWeights(
        planes=planes, centers=centers, slicing=tuple(slicing),
        shifts=sl.slice_shifts(slicing, sl.WEIGHT_BITS), rows=int(w_u8.shape[0]),
        rows_per_xbar=rows_per_xbar)


def decode(enc: EncodedWeights) -> np.ndarray:
    """Reconstruct the unsigned 8b weight matrix (exactness check)."""
    n_slices, n_seg, R, cols = enc.planes.shape
    r = np.zeros((n_seg, R, cols), dtype=np.int64)
    for j, l in enumerate(enc.shifts):
        r += enc.planes[j].astype(np.int64) << l
    w = r + enc.centers[:, None, :]
    w = w.reshape(n_seg * R, cols)[: enc.rows]
    return w


def center_term(x_u8: jnp.ndarray, enc: EncodedWeights) -> jnp.ndarray:
    """The digital term phi * sum(I) of Eq. 1, per segment, summed.

    x_u8: (..., rows) unsigned 8b inputs -> (..., cols) int32.
    """
    rows_pad = enc.n_segments * enc.rows_per_xbar
    pad = rows_pad - x_u8.shape[-1]
    xp = jnp.pad(x_u8.astype(jnp.int32), [(0, 0)] * (x_u8.ndim - 1) + [(0, pad)])
    xs = xp.reshape(x_u8.shape[:-1] + (enc.n_segments, enc.rows_per_xbar))
    seg_sums = xs.sum(axis=-1)  # (..., n_seg)
    return jnp.einsum("...s,sc->...c", seg_sums.astype(jnp.int32),
                      jnp.asarray(enc.centers, dtype=jnp.int32))
