"""RAELLA's contribution as a composable JAX library.

Submodules:
  slicing        bit-slice arithmetic (D(h,l,x), 108 slicings)
  backends       CrossbarBackend ABC: IdealSim / NonidealSim device models
  center_offset  Eq. 2 center solve + 2T2R offset encoding
  adc            7b saturating ADC + analog noise model
  crossbar       bit-exact 512-row crossbar forward
  speculation    dynamic input slicing (speculate/recover)
  adaptive       Algorithm 1 adaptive weight slicing
  pim_linear     RaellaLinear layer (exact | fast | off)
  energy         Titanium Law + component energy/throughput model
  mapping        layer -> crossbar/IMA/tile mapping & replication
  workloads      the paper's seven evaluation DNNs
"""

from repro.core import (  # noqa: F401
    adaptive,
    adc,
    backends,
    center_offset,
    crossbar,
    energy,
    mapping,
    pim_linear,
    slicing,
    speculation,
    workloads,
)
