"""Bit-slicing arithmetic (paper §2.3, §4.1.3, §4.2.2).

A *slicing* of an M-bit operand is a tuple of slice widths ``(s_0, ..., s_k)``,
MSB-first, with ``sum(s_i) == M`` and every ``s_i <= MAX_DEVICE_BITS``. Slice
``i`` covers the inclusive bit range ``[h_i .. l_i]``.

The paper's ``D(h, l, x)`` crops a *signed* integer to the bits ``[h..l]`` of
its magnitude, preserving the sign (sign-magnitude slicing — this is how
offsets are programmed into the positive/negative ReRAM of a 2T2R pair).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax.numpy as jnp

WEIGHT_BITS = 8
INPUT_BITS = 8
MAX_DEVICE_BITS = 4  # ReRAMs programmable up to 4b in RAELLA (5b shown feasible)


@functools.lru_cache(maxsize=None)
def enumerate_slicings(total_bits: int = WEIGHT_BITS,
                       max_bits: int = MAX_DEVICE_BITS) -> tuple[tuple[int, ...], ...]:
    """All compositions of ``total_bits`` into parts of size 1..max_bits.

    For 8 bits and <=4b devices this yields the paper's 108 slicings.
    MSB-first ordering of the parts.
    """
    if total_bits == 0:
        return ((),)
    out = []
    for first in range(1, min(max_bits, total_bits) + 1):
        for rest in enumerate_slicings(total_bits - first, max_bits):
            out.append((first,) + rest)
    return tuple(out)


def slice_bounds(slicing: Sequence[int],
                 total_bits: int | None = None) -> tuple[tuple[int, int], ...]:
    """Inclusive (h, l) bit bounds per slice, MSB-first.

    ``slicing=(4,2,2)`` over 8 bits -> ((7,4), (3,2), (1,0)).
    """
    total = sum(slicing) if total_bits is None else total_bits
    if total_bits is not None and sum(slicing) != total_bits:
        raise ValueError(f"slicing {slicing} does not cover {total_bits} bits")
    bounds = []
    h = total - 1
    for s in slicing:
        bounds.append((h, h - s + 1))
        h -= s
    return tuple(bounds)


def crop_signed(x, h: int, l: int):
    """The paper's D(h, l, x): bits [h..l] of |x|, shifted down by l, signed.

    Works on jnp or np integer arrays.
    """
    mask = (1 << (h - l + 1)) - 1
    mag = jnp.abs(x).astype(jnp.int32)
    return jnp.sign(x).astype(jnp.int32) * ((mag >> l) & mask)


def crop_unsigned(x, h: int, l: int):
    """Bits [h..l] of a non-negative integer, shifted down by l."""
    mask = (1 << (h - l + 1)) - 1
    return (x.astype(jnp.int32) >> l) & mask


def slice_signed(x, slicing: Sequence[int], total_bits: int = WEIGHT_BITS):
    """Sign-magnitude slices of signed x, MSB-first: list of int32 arrays."""
    return [crop_signed(x, h, l) for h, l in slice_bounds(slicing, total_bits)]


def slice_unsigned(x, slicing: Sequence[int], total_bits: int = INPUT_BITS):
    """Unsigned slices of non-negative x, MSB-first: list of int32 arrays."""
    return [crop_unsigned(x, h, l) for h, l in slice_bounds(slicing, total_bits)]


def slice_shifts(slicing: Sequence[int], total_bits: int | None = None) -> tuple[int, ...]:
    """Power-of-two shift (2**l) applied when recombining each slice."""
    return tuple(l for _, l in slice_bounds(slicing, total_bits))


def reconstruct(slices, slicing: Sequence[int], total_bits: int | None = None):
    """Inverse of slice_signed / slice_unsigned: sum_i 2**l_i * slice_i."""
    out = 0
    for s, (_, l) in zip(slices, slice_bounds(slicing, total_bits)):
        out = out + (s.astype(jnp.int32) << l)
    return out


def reslice_to_1b(slice_val, width: int):
    """Re-slice one signed slice (width bits) into ``width`` 1b sub-slices.

    Used by recovery (paper §4.3): a failed 4b speculative input slice is
    re-processed as four 1b slices. Returns list MSB-first with local shifts
    (width-1 .. 0).
    """
    return [crop_signed(slice_val, b, b) for b in range(width - 1, -1, -1)]


def to_unsigned_weights(w_int8):
    """Map signed int8 weights to the unsigned 8b domain used on-crossbar.

    w_u = w + 128 in [0, 255]. The -128 constant folds into the digital
    center term (see core.center_offset / quant.quantize dequant algebra).
    """
    return (w_int8.astype(jnp.int32) + 128).astype(jnp.int32)


def np_enumerate_slicings_count() -> int:
    return len(enumerate_slicings())


assert len(enumerate_slicings()) == 108, "paper: 108 slicings of 8b with <=4b/slice"
