"""Layer -> crossbar/IMA/tile mapping (paper §5).

Maps DNN layers onto a PIM accelerator: filter segmentation over 512-row
crossbars, column packing (n_weight_slices columns per filter), utilization
accounting, partial-Toeplitz in-crossbar replication, and the greedy
cross-tile replication scheme ("while there are tiles left, the
lowest-throughput layer is replicated").
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One weight-stationary layer of a DNN workload.

    filter_len: rows of one dot product (k*k*Cin for conv, d_in for FC).
    n_filters:  output channels / columns of the weight matrix.
    n_positions: output positions sharing the weights (H_out*W_out for conv,
                 tokens for FC/attention projections; 1 for a single MVM).
    signed_inputs: True -> two-cycle positive/negative input processing.
    depthwise:  depthwise conv — each filter sees only its own channel
                (n_filters independent k*k dot products).
    """
    name: str
    filter_len: int
    n_filters: int
    n_positions: int
    signed_inputs: bool = False
    depthwise: bool = False
    last_layer: bool = False
    row_positions: int = 0   # output positions per dataflow "row" (paper §5.5:
                             # tiles emit one output-tensor row at a time —
                             # this caps useful weight replication). 0 -> 1.

    @property
    def macs(self) -> int:
        return self.filter_len * self.n_filters * self.n_positions

    @property
    def weights(self) -> int:
        return self.filter_len * self.n_filters


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    layer: LayerShape
    n_segments: int          # vertical filter splits across crossbars
    rows_used: int           # rows occupied in the (last) segment pattern
    utilization: float       # used rows / provisioned rows
    filters_per_xbar: int    # filters packed side by side in one crossbar
    toeplitz_positions: int  # output positions computed per crossbar pass
    n_crossbars: int         # crossbars to hold one copy of the layer
    replication: int = 1     # copies (greedy throughput replication)


def map_layer(layer: LayerShape, rows: int, cols: int,
              n_weight_slices: int) -> LayerMapping:
    """Pack one layer onto crossbars of (rows x cols) with spatial slicing."""
    flen = layer.filter_len
    n_seg = max(1, math.ceil(flen / rows))
    per_seg_rows = min(flen, rows)
    cols_per_filter = n_weight_slices
    filters_per_xbar = max(1, cols // cols_per_filter)

    # partial Toeplitz (paper §5.5, [11]): if a conv filter leaves row slack,
    # replicate the filter shifted in-crossbar to produce several output
    # positions per pass. FCs (n_positions==1 per token) get no benefit.
    toeplitz = 1
    if n_seg == 1 and layer.n_positions > 1 and not layer.depthwise:
        toeplitz = min(max(1, rows // flen), 8)  # diminishing returns cap
    rows_used = min(rows, per_seg_rows * toeplitz)

    if layer.depthwise:
        # each filter is its own tiny dot product; rows utilization is poor
        rows_used = min(rows, flen * toeplitz)
    n_xbars_for_filters = math.ceil(layer.n_filters / filters_per_xbar)
    n_crossbars = n_seg * n_xbars_for_filters
    util = (min(flen, rows * n_seg) / (rows * n_seg)) if not layer.depthwise \
        else min(1.0, rows_used / rows)
    return LayerMapping(
        layer=layer, n_segments=n_seg, rows_used=rows_used,
        utilization=util, filters_per_xbar=filters_per_xbar,
        toeplitz_positions=toeplitz, n_crossbars=n_crossbars)


def greedy_replicate(mappings: list[LayerMapping],
                     latencies: list[float],
                     total_crossbars: int) -> list[LayerMapping]:
    """Paper §5.5: while crossbars remain, replicate the slowest layer.

    Replication of layer i is capped at the number of output positions per
    dataflow row not already covered in-crossbar (row-synchronous pipeline:
    extra copies beyond one row of work sit idle).
    """
    base = sum(m.n_crossbars for m in mappings)
    if base > total_crossbars:
        return mappings  # does not fit with replication; single copy spill
    caps = [max(1, math.ceil(m.layer.n_positions / m.toeplitz_positions))
            for m in mappings]
    costs = [m.n_crossbars for m in mappings]

    def reps_for(target: float) -> list[int]:
        # copies needed so every layer's effective latency <= target
        return [max(1, min(cap, math.ceil(lat / max(target, 1e-9))))
                for lat, cap in zip(latencies, caps)]

    # water-filling via binary search on the bottleneck latency (equivalent
    # to the paper's greedy loop, but O(L log T) instead of O(copies * L))
    lo, hi = 0.0, max(latencies) if latencies else 0.0
    best = [1] * len(mappings)
    for _ in range(60):
        mid = (lo + hi) / 2 if hi > 0 else 0.0
        r = reps_for(mid)
        if sum(c * ri for c, ri in zip(costs, r)) <= total_crossbars:
            best, hi = r, mid
        else:
            lo = mid
    return [dataclasses.replace(m, replication=r)
            for m, r in zip(mappings, best)]
