"""Adaptive Weight Slicing — the paper's Algorithm 1 (§4.2).

For each DNN layer, pick the weight slicing with the *fewest slices* whose
measured error is under the error budget (0.09: "one in eleven 8b outputs
off by one on average"), tie-broken by lower error. Error is measured
empirically: run ~10 calibration inputs through the bit-exact crossbar
simulation (1b input slices, per the paper), requantize to 8b output codes,
and compare against the ideal 8b-quantized layer on nonzero expected outputs.

The search is noise-aware: passing a noise level makes the chosen slicing
automatically more conservative (Fig. 15's adaptivity claim).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import pim_linear as pl
from repro.core import slicing as sl
from repro.quant import quantize as q

ERROR_BUDGET = 0.09  # paper §4.2.1


@dataclasses.dataclass
class SlicingChoice:
    slicing: tuple[int, ...]
    error: float
    n_slices: int
    all_errors: dict  # slicing -> measured error (for the tried subset)


def measure_error(w: jnp.ndarray, x_cal: jnp.ndarray,
                  weight_slicing: Sequence[int], *,
                  adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
                  encode_mode: str = "center",
                  noise_level: float = 0.0,
                  key: jax.Array | None = None,
                  relu_out: bool = False) -> float:
    """Mean |8b-output error| on nonzero expected outputs (paper §4.2.1)."""
    plan = pl.prepare(w, x_cal, weight_slicing=weight_slicing, adc=adc,
                      speculation=False, encode_mode=encode_mode,
                      relu_out=relu_out)
    # paper: 1b input slices while comparing weight slicings
    y_sim = pl.forward_exact(x_cal, plan, input_slicing=(1,) * sl.INPUT_BITS,
                             noise_level=noise_level, key=key)
    y_ref = pl.forward_int_reference(x_cal, plan)
    out_sim = pl.output_codes(y_sim, plan, relu=relu_out)
    out_ref = pl.output_codes(y_ref, plan, relu=relu_out)
    nz = out_ref != 0
    err = jnp.abs(out_sim - out_ref).astype(jnp.float32)
    denom = jnp.maximum(nz.sum(), 1)
    return float(jnp.where(nz, err, 0.0).sum() / denom)


def candidate_slicings(max_slices: int = 8,
                       full_search: bool = False) -> tuple[tuple[int, ...], ...]:
    """Slicings ordered by (n_slices, MSB-heaviness).

    full_search=True iterates all 108 (paper). Otherwise a pruned front: for
    each slice count, MSB-first-largest layouts — these dominate in practice
    because high-order weight bits are sparse after centering (Fig. 8), so
    giving the MSB slice the most bits is the efficient direction.
    """
    all_s = sl.enumerate_slicings()
    if full_search:
        return tuple(sorted(all_s, key=lambda s: (len(s), [-b for b in s])))
    pruned = [s for s in all_s
              if list(s) == sorted(s, reverse=True)]  # non-increasing widths
    return tuple(sorted(pruned, key=lambda s: (len(s), [-b for b in s])))


def find_best_slicing(w: jnp.ndarray, x_cal: jnp.ndarray, *,
                      error_budget: float = ERROR_BUDGET,
                      adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
                      encode_mode: str = "center",
                      noise_level: float = 0.0,
                      key: jax.Array | None = None,
                      relu_out: bool = False,
                      full_search: bool = False,
                      last_layer: bool = False) -> SlicingChoice:
    """Algorithm 1's FindBestSlicing.

    last_layer=True forces the most conservative 1b-per-slice slicing
    (paper: the last layer has an outsized accuracy effect).
    """
    if last_layer:
        s = (1,) * sl.WEIGHT_BITS
        e = measure_error(w, x_cal, s, adc=adc, encode_mode=encode_mode,
                          noise_level=noise_level, key=key, relu_out=relu_out)
        return SlicingChoice(s, e, len(s), {s: e})
    errors: dict = {}
    best = None
    cands = candidate_slicings(full_search=full_search)
    cur_n = None
    group_best: tuple[float, tuple[int, ...]] | None = None
    for s in cands:
        if cur_n is not None and len(s) != cur_n and group_best is not None:
            break  # a smaller-slice-count group already satisfied the budget
        cur_n = len(s)
        e = measure_error(w, x_cal, s, adc=adc, encode_mode=encode_mode,
                          noise_level=noise_level, key=key, relu_out=relu_out)
        errors[s] = e
        if e < error_budget and (group_best is None or e < group_best[0]):
            group_best = (e, s)
    if group_best is None:
        # nothing under budget: fall back to the most conservative slicing
        s = (1,) * sl.WEIGHT_BITS
        e = errors.get(s)
        if e is None:
            e = measure_error(w, x_cal, s, adc=adc, encode_mode=encode_mode,
                              noise_level=noise_level, key=key, relu_out=relu_out)
            errors[s] = e
        group_best = (e, s)
    e, s = group_best
    return SlicingChoice(slicing=s, error=e, n_slices=len(s), all_errors=errors)
