"""Adaptive Weight Slicing — the paper's Algorithm 1 (§4.2).

For each DNN layer, pick the weight slicing with the *fewest slices* whose
measured error is under the error budget (0.09: "one in eleven 8b outputs
off by one on average"), tie-broken by lower error. Error is measured
empirically: run ~10 calibration inputs through the bit-exact crossbar
simulation (1b input slices, per the paper), requantize to 8b output codes,
and compare against the ideal 8b-quantized layer on nonzero expected outputs.

The search is noise-aware: passing a noise level makes the chosen slicing
automatically more conservative (Fig. 15's adaptivity claim).

``find_best_slicing`` evaluates one slice-count group of candidates at a
time and fetches the whole group's errors with a single host sync
(``measure_errors``) — the per-site model compiler
(``repro.models.pim_compile``) calls this once per projection site, so a
``float()`` round-trip per candidate would serialize the entire compile on
host<->device latency.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adc as adc_lib
from repro.core import pim_linear as pl
from repro.core import slicing as sl

ERROR_BUDGET = 0.09  # paper §4.2.1


@dataclasses.dataclass
class SlicingChoice:
    slicing: tuple[int, ...]
    error: float
    n_slices: int
    all_errors: dict  # slicing -> measured error (for the tried subset)


def _error_value(w: jnp.ndarray, x_cal: jnp.ndarray,
                 weight_slicing: Sequence[int], *,
                 adc: adc_lib.ADCConfig,
                 encode_mode: str,
                 noise_level: float,
                 key: jax.Array | None,
                 relu_out: bool) -> jnp.ndarray:
    """Device-side §4.2.1 error (scalar jnp array — no host sync)."""
    plan = pl.prepare(w, x_cal, weight_slicing=weight_slicing, adc=adc,
                      speculation=False, encode_mode=encode_mode,
                      relu_out=relu_out)
    # paper: 1b input slices while comparing weight slicings
    y_sim = pl.forward_exact(x_cal, plan, input_slicing=(1,) * sl.INPUT_BITS,
                             noise_level=noise_level, key=key)
    y_ref = pl.forward_int_reference(x_cal, plan)
    out_sim = pl.output_codes(y_sim, plan, relu=relu_out)
    out_ref = pl.output_codes(y_ref, plan, relu=relu_out)
    nz = out_ref != 0
    err = jnp.abs(out_sim - out_ref).astype(jnp.float32)
    denom = jnp.maximum(nz.sum(), 1)
    return jnp.where(nz, err, 0.0).sum() / denom


def measure_error(w: jnp.ndarray, x_cal: jnp.ndarray,
                  weight_slicing: Sequence[int], *,
                  adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
                  encode_mode: str = "center",
                  noise_level: float = 0.0,
                  key: jax.Array | None = None,
                  relu_out: bool = False) -> float:
    """Mean |8b-output error| on nonzero expected outputs (paper §4.2.1)."""
    return float(_error_value(w, x_cal, weight_slicing, adc=adc,
                              encode_mode=encode_mode,
                              noise_level=noise_level, key=key,
                              relu_out=relu_out))


def measure_errors(w: jnp.ndarray, x_cal: jnp.ndarray,
                   slicings: Sequence[Sequence[int]], *,
                   adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
                   encode_mode: str = "center",
                   noise_level: float = 0.0,
                   key: jax.Array | None = None,
                   relu_out: bool = False) -> np.ndarray:
    """``measure_error`` over many candidate slicings, one host sync total.

    Every candidate's simulation is dispatched before any result is
    fetched, so the device pipeline stays full instead of blocking on a
    ``float()`` round-trip per candidate.
    """
    vals = [_error_value(w, x_cal, s, adc=adc, encode_mode=encode_mode,
                         noise_level=noise_level, key=key, relu_out=relu_out)
            for s in slicings]
    if not vals:
        return np.zeros((0,), np.float32)
    return np.asarray(jax.device_get(vals), np.float32)


def candidate_slicings(max_slices: int = 8,
                       full_search: bool = False) -> tuple[tuple[int, ...], ...]:
    """Slicings ordered by (n_slices, MSB-heaviness).

    full_search=True iterates all 108 (paper). Otherwise a pruned front: for
    each slice count, MSB-first-largest layouts — these dominate in practice
    because high-order weight bits are sparse after centering (Fig. 8), so
    giving the MSB slice the most bits is the efficient direction.
    """
    all_s = sl.enumerate_slicings()
    if full_search:
        return tuple(sorted(all_s, key=lambda s: (len(s), [-b for b in s])))
    pruned = [s for s in all_s
              if list(s) == sorted(s, reverse=True)]  # non-increasing widths
    return tuple(sorted(pruned, key=lambda s: (len(s), [-b for b in s])))


def find_best_slicing(w: jnp.ndarray, x_cal: jnp.ndarray, *,
                      error_budget: float = ERROR_BUDGET,
                      adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
                      encode_mode: str = "center",
                      noise_level: float = 0.0,
                      key: jax.Array | None = None,
                      relu_out: bool = False,
                      full_search: bool = False,
                      last_layer: bool = False) -> SlicingChoice:
    """Algorithm 1's FindBestSlicing.

    last_layer=True forces the most conservative 1b-per-slice slicing
    (paper: the last layer has an outsized accuracy effect).

    Candidates are evaluated a slice-count group at a time (fewest slices
    first); the first group with an under-budget member wins, tie-broken by
    lower error within the group. Each group is fetched with one host sync.
    """
    kwargs = dict(adc=adc, encode_mode=encode_mode, noise_level=noise_level,
                  key=key, relu_out=relu_out)
    if last_layer:
        s = (1,) * sl.WEIGHT_BITS
        e = measure_error(w, x_cal, s, **kwargs)
        return SlicingChoice(s, e, len(s), {s: e})
    errors: dict = {}
    cands = candidate_slicings(full_search=full_search)
    for _, group in itertools.groupby(cands, key=len):
        group = tuple(group)
        errs = measure_errors(w, x_cal, group, **kwargs)
        best: tuple[float, tuple[int, ...]] | None = None
        for s, e in zip(group, errs):
            errors[s] = float(e)
            if e < error_budget and (best is None or e < best[0]):
                best = (float(e), s)
        if best is not None:
            e, s = best
            return SlicingChoice(slicing=s, error=e, n_slices=len(s),
                                 all_errors=errors)
    # nothing under budget: fall back to the most conservative slicing
    s = (1,) * sl.WEIGHT_BITS
    e = errors.get(s)
    if e is None:
        e = measure_error(w, x_cal, s, **kwargs)
        errors[s] = e
    return SlicingChoice(slicing=s, error=e, n_slices=len(s),
                         all_errors=errors)
