"""Device-realism crossbar backends: an abstract analog-array model.

RAELLA's accuracy claim — low-resolution PIM *without retraining* — is
only credible if the simulated array behaves like ReRAM silicon, not
like an exact integer matmul. This module splits the analog read out of
``core.crossbar.forward`` behind a pure-abstract ``CrossbarBackend``
(mirroring daffodil-lib's ``Daffodil_Base`` / ``Daffodil_Sim`` split:
program once, read many):

  ``IdealSim``     the exact integer 2T2R model the repo always had —
                   signed slice planes as (G+, G-) integer conductances,
                   int32 column sums. ``crossbar.forward`` keeps routing
                   its noiseless runs through the fused Pallas kernel.

  ``NonidealSim``  a ReRAM die. ``program`` perturbs the conductances
                   with the four dominant eNVM nonidealities, composed
                   in physical order:

                     1. conductance program error — per-device
                        multiplicative lognormal, ``G * exp(sigma * n)``
                        (write-and-verify leaves relative error);
                     2. retention drift — ``G * (t / t0)^(-nu)`` with
                        ``t0 = 1 s``, time-parameterized per corner;
                     3. stuck-at faults — per-device Bernoulli maps,
                        stuck-at-G_on or stuck-at-G_off (forming faults /
                        broken filaments), deterministic in the die key;
                     4. IR drop — first-order attenuation of each row's
                        contribution by its distance along the bitline
                        from the sense amp.

All draws key off the ``NonidealSim.key`` (the *die*), never off a
per-call RNG: the same die reads the same way every forward pass, which
is what makes corner sweeps of a fixed compiled plan meaningful. The
whole model is pure-functional jnp, jit-safe, and vmappable over
``DeviceCorner`` pytrees (``stack_corners``).

Zero-corner contract: a ``NonidealSim`` whose corner magnitudes are all
zero is **bit-exact** with ``IdealSim`` (and with the fused kernel).
This is arranged, not lucky: every perturbation is a multiply by a
factor that is exactly 1.0 (``exp(+-0.0)``, ``1 - 0*x``) or a
``jnp.where`` on an all-False mask at zero magnitude, and the float32
column-sum einsum is exact because every partial sum is an integer below
2^24 (|slice| <= 127, inputs <= 255, <= 512 rows: max 16.6M < 2^24).
``tests/test_nonideal_backend.py`` pins all of it.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceCorner:
    """One die's nonideality magnitudes. All-zero (the default) is the
    nominal corner, bit-exact with the ideal sim. Fields are pytree data
    (python floats or traced scalars), so corners stack and vmap."""
    program_sigma: float = 0.0   # lognormal conductance write error (rel.)
    drift_nu: float = 0.0        # retention drift exponent
    drift_time: float = 0.0      # seconds since programming (t0 = 1 s)
    stuck_rate: float = 0.0      # per-device stuck-at fault probability
    stuck_on_frac: float = 0.5   # of stuck devices, fraction at G_on
    ir_drop_alpha: float = 0.0   # bitline attenuation at the far row


# Named corners for the fig15/table4 sweeps and `serve --device-corner`.
# 1sigma ~ a typical production die (write-verify to ~3% conductance,
# ~1e-3 fault density, day-scale retention); 3sigma ~ a tail die.
NOMINAL = DeviceCorner()
SIGMA1 = DeviceCorner(program_sigma=0.03, drift_nu=0.01, drift_time=1e5,
                      stuck_rate=1e-3, ir_drop_alpha=0.02)
SIGMA3 = DeviceCorner(program_sigma=0.09, drift_nu=0.03, drift_time=1e5,
                      stuck_rate=5e-3, ir_drop_alpha=0.06)
CORNERS: dict[str, DeviceCorner] = {
    "nominal": NOMINAL, "1sigma": SIGMA1, "3sigma": SIGMA3,
}


def corner(name: str) -> DeviceCorner:
    """Look up a named corner (``'nominal'`` / ``'1sigma'`` / ``'3sigma'``)."""
    if name not in CORNERS:
        raise ValueError(f"unknown device corner {name!r}; "
                         f"have {sorted(CORNERS)}")
    return CORNERS[name]


def stack_corners(corners_: list[DeviceCorner] | tuple[DeviceCorner, ...]
                  ) -> DeviceCorner:
    """Stack corners leaf-wise into one vmappable DeviceCorner pytree."""
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
        *corners_)


class ProgrammedPlanes(NamedTuple):
    """The programmed array: per-plane (G+, G-) conductances plus the
    stuck-at fault maps (None for the ideal sim). ``gp``/``gn`` are
    (n_slices, n_seg, rows_per_xbar, cols); fault maps add a leading
    device axis of 2 (positive / negative ReRAM of each 2T2R pair)."""
    gp: jnp.ndarray
    gn: jnp.ndarray
    stuck_on: jnp.ndarray | None = None
    stuck_off: jnp.ndarray | None = None


class CrossbarBackend(abc.ABC):
    """Abstract analog array: write-once (``program``), read-many
    (``read``). Implementations must be pure functions of their inputs
    and their own fields — no internal state, so the whole datapath
    stays jit/vmap-safe."""

    name: str = "abstract"

    @abc.abstractmethod
    def program(self, planes: jnp.ndarray, *,
                rows: int | None = None) -> ProgrammedPlanes:
        """Program signed slice planes (n_slices, n_seg, R, C) into
        (G+, G-) conductance arrays. ``rows`` is the true (unpadded)
        input length: simulation-padding rows beyond it hold no physical
        devices, so nonidealities never touch them."""

    @abc.abstractmethod
    def read(self, prog: ProgrammedPlanes, x_slice: jnp.ndarray,
             j: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Analog column sums of one input slice against plane ``j``.
        x_slice: (B, n_seg, R) unsigned slice values. Returns
        (pos, neg) of shape (B, n_seg, C) — their difference is the
        column sum the ADC converts."""


class IdealSim(CrossbarBackend):
    """The exact integer 2T2R model (the repo's historical behavior).
    ``crossbar.forward`` treats this backend as fused-kernel eligible."""

    name = "ideal"

    def program(self, planes: jnp.ndarray, *,
                rows: int | None = None) -> ProgrammedPlanes:
        p = jnp.asarray(planes).astype(jnp.int32)
        return ProgrammedPlanes(gp=jnp.maximum(p, 0), gn=jnp.maximum(-p, 0))

    def read(self, prog: ProgrammedPlanes, x_slice: jnp.ndarray,
             j: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        x = x_slice.astype(jnp.int32)
        pos = jnp.einsum("bsr,src->bsc", x, prog.gp[j],
                         preferred_element_type=jnp.int32)
        neg = jnp.einsum("bsr,src->bsc", x, prog.gn[j],
                         preferred_element_type=jnp.int32)
        return pos, neg


IDEAL = IdealSim()


@dataclasses.dataclass(frozen=True)
class NonidealSim(CrossbarBackend):
    """One ReRAM die: a ``DeviceCorner`` plus the die key that seeds its
    fault maps and write-error draws. Deterministic — the same
    (corner, key) pair programs the identical die every time."""

    corner: DeviceCorner = NOMINAL
    key: jax.Array | None = None

    name = "nonideal"

    def _key(self) -> jax.Array:
        return self.key if self.key is not None else jax.random.key(0)

    def program(self, planes: jnp.ndarray, *,
                rows: int | None = None) -> ProgrammedPlanes:
        planes = jnp.asarray(planes)
        n_w, n_seg, R, C = planes.shape
        p = planes.astype(jnp.float32)
        gp, gn = jnp.maximum(p, 0.0), jnp.maximum(-p, 0.0)
        c = self.corner
        kp, kn, kfp, kfn, kop, kon = jax.random.split(self._key(), 6)

        # 1. conductance program error: per-device lognormal. sigma = 0
        #    multiplies by exp(+-0.0) == 1.0 exactly.
        sigma = jnp.asarray(c.program_sigma, jnp.float32)
        gp = gp * jnp.exp(sigma * jax.random.normal(kp, p.shape, jnp.float32))
        gn = gn * jnp.exp(sigma * jax.random.normal(kn, p.shape, jnp.float32))

        # 2. retention drift: G(t) = G0 * (t/t0)^(-nu), t0 = 1 s, clamped
        #    to t >= t0 (no "anti-drift" before one second). nu = 0 gives
        #    exp(-0.0 * log) == 1.0 exactly.
        nu = jnp.asarray(c.drift_nu, jnp.float32)
        t = jnp.maximum(jnp.asarray(c.drift_time, jnp.float32), 1.0)
        drift = jnp.exp(-nu * jnp.log(t))
        gp, gn = gp * drift, gn * drift

        # 3. stuck-at fault maps: Bernoulli per physical device, keyed by
        #    the die. G_on is approximated by the largest programmed
        #    magnitude in the plane — an all-zero (padding) plane has
        #    G_on = 0, so the slice-padding contract survives faults; and
        #    rows beyond `rows` (segment zero-padding) hold no devices.
        if rows is None:
            rows = n_seg * R
        live = (jnp.arange(n_seg * R).reshape(n_seg, R) < rows)[None, :, :, None]
        rate = jnp.asarray(c.stuck_rate, jnp.float32)
        onf = jnp.asarray(c.stuck_on_frac, jnp.float32)
        g_on = jnp.max(jnp.abs(p), axis=(1, 2, 3), keepdims=True)

        def stuck(g, kf, ko):
            s = (jax.random.uniform(kf, p.shape) < rate) & live
            on = jax.random.uniform(ko, p.shape) < onf
            s_on, s_off = s & on, s & ~on
            g = jnp.where(s_on, g_on, g)
            g = jnp.where(s_off, 0.0, g)
            return g, s_on, s_off

        gp, on_p, off_p = stuck(gp, kfp, kop)
        gn, on_n, off_n = stuck(gn, kfn, kon)

        # 4. IR drop: rows far from the sense amp lose drive along the
        #    bitline; first-order linear attenuation, alpha = fractional
        #    loss at the far end. alpha = 0 scales by exactly 1.0.
        alpha = jnp.asarray(c.ir_drop_alpha, jnp.float32)
        att = 1.0 - alpha * (jnp.arange(R, dtype=jnp.float32) / max(R - 1, 1))
        gp = gp * att[None, None, :, None]
        gn = gn * att[None, None, :, None]
        return ProgrammedPlanes(
            gp=gp, gn=gn,
            stuck_on=jnp.stack([on_p, on_n]),
            stuck_off=jnp.stack([off_p, off_n]))

    def read(self, prog: ProgrammedPlanes, x_slice: jnp.ndarray,
             j: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        # float32 is exact here: every partial sum is an integer-valued
        # quantity below 2^24 at the zero corner (see module docstring).
        x = x_slice.astype(jnp.float32)
        pos = jnp.einsum("bsr,src->bsc", x, prog.gp[j])
        neg = jnp.einsum("bsr,src->bsc", x, prog.gn[j])
        return pos, neg


BACKENDS = ("ideal", "nonideal")


def make(name: str, corner_: DeviceCorner | str = "nominal", *,
         seed: int = 0) -> CrossbarBackend:
    """Build a backend from config strings (``ArchConfig`` uses this:
    ``pim_crossbar_backend`` / ``pim_device_corner`` / ``pim_device_seed``)."""
    if name == "ideal":
        return IDEAL
    if name == "nonideal":
        c = corner_ if isinstance(corner_, DeviceCorner) else corner(corner_)
        return NonidealSim(corner=c, key=jax.random.key(seed))
    raise ValueError(f"unknown crossbar backend {name!r}; have {BACKENDS}")
