"""Bridge: assigned LM architectures -> PIM workload tables.

Maps every weight-static matmul of an ArchConfig (QKV/O projections, dense
FFN, MoE experts, SSM projections, LM head) onto ``mapping.LayerShape`` so
the Titanium-Law model can answer: *what would serving this architecture on
RAELLA vs 8b-ISAAC silicon cost?* Dynamic matmuls (attention scores/values,
SSM recurrences) stay digital, exactly as the paper scopes BERT (§6.2).

Notes:
- decode-style serving: one token per step -> n_positions = tokens served;
- activations after SiLU/GELU are signed -> two-cycle input processing
  (the paper's BERT treatment); post-ReLU-free LM blocks are signed;
- MoE: each token exercises top-k experts, so MACs scale by k/E while the
  crossbar footprint holds all E experts (utilization cost PIM pays).
"""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.core.mapping import LayerShape


def from_arch_config(cfg: ArchConfig, tokens: int = 4096) -> list[LayerShape]:
    """Weight-static layers of one full forward over ``tokens`` tokens."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    layers: list[LayerShape] = []

    def fc(name, din, dout, n_tokens=tokens, last=False):
        layers.append(LayerShape(
            name=name, filter_len=din, n_filters=dout, n_positions=n_tokens,
            signed_inputs=True, last_layer=last, row_positions=n_tokens))

    for i, kind in enumerate(cfg.block_pattern):
        for r in range(cfg.n_repeats):
            tag = f"{kind}{i}r{r}"
            if kind == "attn":
                fc(f"{tag}_q", d, cfg.n_heads * hd)
                fc(f"{tag}_k", d, cfg.n_kv_heads * hd)
                fc(f"{tag}_v", d, cfg.n_kv_heads * hd)
                fc(f"{tag}_o", cfg.n_heads * hd, d)
            elif kind == "mamba":
                di = cfg.mamba_expand * d
                fc(f"{tag}_in", d, 2 * di)
                fc(f"{tag}_x", di, max(1, d // 16) + 2 * cfg.mamba_d_state)
                fc(f"{tag}_out", di, d)
            elif kind == "rwkv":
                for nm in ("r", "k", "v", "g", "o"):
                    fc(f"{tag}_{nm}", d, d)
            # FFN
            if kind == "rwkv":
                fc(f"{tag}_cmk", d, cfg.d_ff)
                fc(f"{tag}_cmv", cfg.d_ff, d)
            elif cfg.moe_layer(i):
                # top-k of E experts active per token; weights for all E
                # are resident (footprint), MACs scale with active tokens
                active = max(1, tokens * cfg.experts_per_token
                             // max(cfg.n_experts, 1))
                for e in range(cfg.n_experts):
                    fc(f"{tag}_e{e}w1", d, cfg.d_ff, n_tokens=active)
                    fc(f"{tag}_e{e}w3", d, cfg.d_ff, n_tokens=active)
                    fc(f"{tag}_e{e}w2", cfg.d_ff, d, n_tokens=active)
            else:
                fc(f"{tag}_w1", d, cfg.d_ff)
                fc(f"{tag}_w3", d, cfg.d_ff)
                fc(f"{tag}_w2", cfg.d_ff, d)
    fc("lm_head", d, cfg.vocab_size, last=True)
    return layers
