"""Titanium Law energy/throughput model (paper §2.5, Table 2, §6.1).

    E_ADC = Energy/Convert x Converts/MAC x MACs/DNN x 1/Utilization

plus an Accelergy-style per-component energy model (ADC, DAC/driver, ReRAM
crossbar, buffers, router, eDRAM, digital center processing) and the paper's
throughput model (100ns crossbar cycles, 8 or 3+8 cycles per psum set,
signed inputs doubling cycles, greedy weight replication).

Component constants are calibrated so the model reproduces the paper's
published *ratios* (the paper's own numbers come from Accelergy/Timeloop
models, the same class of evidence):
  - ISAAC energy dominated by ADC (Fig. 1),
  - Converts/MAC 0.25 -> 0.063 -> 0.047 -> 0.018 along the Fig. 14 ablation
    (these are exact combinatorics, not calibration),
  - RAELLA vs ISAAC efficiency ~3.9x geomean / throughput ~2.0x geomean
    (Fig. 12), without speculation ~2.8x / ~2.7x.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import mapping as mp

# ---------------------------------------------------------------- components
# energies in pJ. Effective constants calibrated so the 8b-ISAAC baseline
# reproduces the paper's Fig. 1 energy breakdown (ADC ~51%, the rest split
# across DAC/crossbar/buffers/network/digital); the paper's own numbers come
# from Accelergy/NeuroSim component models we do not possess, so we pin the
# baseline *shares* and let every cross-architecture ratio follow from the
# work counts (converts, cycles, bytes), which are exact combinatorics.
E_ADC_8B = 2.58           # [23] 3.1mW @ 1.2GS/s -> pJ/convert at 8b
ADC_SCALE_PER_BIT = 2.0   # [65]: energy/area scale ~2x per bit
E_DAC_PULSE = 0.1534       # pulse-train driver, per 1ns pulse per row
E_DAC_STATIC = 0.0766      # flip-flop + AND gate per row-cycle
E_CELL_MAX = 0.0492        # ReRAM cell at full input/full conductance, per pulse
E_SRAM_BYTE = 0.3045        # input/psum buffer access per byte
E_EDRAM_BYTE = 7.109        # tile eDRAM per byte
E_ROUTER_BYTE = 12.275      # on-chip network per byte hop
E_DIGITAL_MAC = 1.989      # digital add/mul (center processing, requant)
CYCLE_NS = 100.0          # crossbar pipeline cycle (ADC stage bound)

AVG_INPUT_DENSITY = 0.22  # mean normalized input slice value (Fig. 8 skew)
AVG_WEIGHT_DENSITY = {    # mean normalized |weight slice| value by encoding
    "unsigned": 0.42,     # ISAAC dense high-order bits
    "zero": 0.30,
    "center": 0.17,       # Center+Offset sparse high-order bits (Fig. 8)
}


def adc_energy_per_convert(bits: int) -> float:
    return E_ADC_8B * ADC_SCALE_PER_BIT ** (bits - 8)


# ---------------------------------------------------------------- arch presets
@dataclasses.dataclass(frozen=True)
class PimArchConfig:
    name: str
    rows: int
    cols: int
    adc_bits: int
    n_weight_slices: int          # typical (adaptive archs override per layer)
    bits_per_weight_slice: float
    input_slices: int             # cycles per input (no speculation)
    spec_slices: int = 0          # speculative cycles (0 = no speculation)
    spec_fail_rate: float = 0.02  # paper: ~2% of speculations fail
    signed_crossbar: bool = False # 2T2R
    encoding: str = "unsigned"    # "unsigned" | "zero" | "center"
    tiles: int = 1024
    crossbars_per_tile: int = 32  # 8 IMAs x 4 crossbars (RAELLA §5)
    adaptive_slicing: bool = False
    two_cycle_signed: bool = True # RAELLA: pos/neg inputs in separate cycles;
                                  # ISAAC's encoding handles sign in one pass

    @property
    def total_crossbars(self) -> int:
        return self.tiles * self.crossbars_per_tile

    def cycles_per_psum_set(self, signed_inputs: bool) -> int:
        c = (self.spec_slices + self.input_slices) if self.spec_slices \
            else self.input_slices
        return c * (2 if (signed_inputs and self.two_cycle_signed) else 1)

    def converts_per_column_pass(self) -> float:
        """ADC converts needed to process one column over all input cycles."""
        if self.spec_slices:
            # paper §4.3.2: 3 speculative converts + ~0.3 recovery converts
            avg_recovery = self.spec_fail_rate * (8 / self.spec_slices)
            return self.spec_slices + avg_recovery * self.spec_slices
        return self.input_slices


ISAAC_8B = PimArchConfig(
    name="isaac-8b", rows=128, cols=128, adc_bits=8,
    n_weight_slices=4, bits_per_weight_slice=2, input_slices=8,
    signed_crossbar=False, encoding="unsigned", tiles=1024,
    crossbars_per_tile=64,  # 8b-modified ISAAC: 8b ADCs cost more area per
                            # crossbar than the original 16b pipeline's, so
                            # fewer crossbars fit a tile (8 IMAs x 8 xbars)
    two_cycle_signed=False)  # ISAAC's input encoding handles sign in one pass

RAELLA = PimArchConfig(
    name="raella", rows=512, cols=512, adc_bits=7,
    n_weight_slices=3, bits_per_weight_slice=8 / 3, input_slices=8,
    spec_slices=3, signed_crossbar=True, encoding="center", tiles=743,
    adaptive_slicing=True)

RAELLA_NO_SPEC = dataclasses.replace(RAELLA, name="raella-nospec", spec_slices=0)

# ablation intermediates (Fig. 14)
CENTER_OFFSET_ONLY = dataclasses.replace(
    RAELLA, name="center-offset", n_weight_slices=4, bits_per_weight_slice=2,
    spec_slices=0, adaptive_slicing=False)
CENTER_ADAPTIVE = dataclasses.replace(
    RAELLA, name="center-adaptive", spec_slices=0)

# FORMS-8: polarized fine-grained pruned ISAAC-like; prune ratio from paper
FORMS_8 = dataclasses.replace(
    ISAAC_8B, name="forms-8", adc_bits=5, rows=128,
    n_weight_slices=8, bits_per_weight_slice=1)
FORMS_PRUNE_RATIO = 2.0  # paper §2.6: 2.0x MACs/DNN reduction on ResNet18

# TIMELY (65nm, analog-local-buffers; paper Fig. 13) — modeled at the
# converts/MAC level only, with its reported 10x efficiency vs ISAAC class.
TIMELY_REL_EFFICIENCY = 10.0


# ---------------------------------------------------------------- energy model
@dataclasses.dataclass
class LayerReport:
    layer: mp.LayerShape
    mapping: mp.LayerMapping
    converts: float
    converts_per_mac: float
    e_adc: float
    e_dac: float
    e_xbar: float
    e_buffer: float
    e_network: float
    e_digital: float
    latency_ns: float

    @property
    def energy(self) -> float:
        return (self.e_adc + self.e_dac + self.e_xbar + self.e_buffer
                + self.e_network + self.e_digital)


@dataclasses.dataclass
class DnnReport:
    arch: str
    layers: list[LayerReport]

    @property
    def energy(self) -> float:
        return sum(l.energy for l in self.layers)

    @property
    def macs(self) -> int:
        return sum(l.layer.macs for l in self.layers)

    @property
    def converts_per_mac(self) -> float:
        return sum(l.converts for l in self.layers) / max(self.macs, 1)

    @property
    def latency_ns(self) -> float:
        """Pipelined: bottleneck layer bounds steady-state throughput."""
        return max(l.latency_ns / l.mapping.replication for l in self.layers)

    @property
    def energy_breakdown(self) -> dict:
        keys = ["e_adc", "e_dac", "e_xbar", "e_buffer", "e_network", "e_digital"]
        return {k: sum(getattr(l, k) for l in self.layers) for k in keys}


def _layer_weight_slices(arch: PimArchConfig, layer: mp.LayerShape) -> float:
    """Adaptive slicing outcome (Fig. 7): most layers 3 slices (4b-2b-2b),
    last layer 8x1b, tiny/depthwise layers conservative 4."""
    if not arch.adaptive_slicing:
        return arch.n_weight_slices
    if layer.last_layer:
        return 8.0
    if layer.depthwise or layer.filter_len < 64:
        return 4.0
    return 3.0


def analyze_layer(arch: PimArchConfig, layer: mp.LayerShape) -> LayerReport:
    n_w = _layer_weight_slices(arch, layer)
    m = mp.map_layer(layer, arch.rows, arch.cols, int(n_w))
    signed = layer.signed_inputs
    cycles = arch.cycles_per_psum_set(signed)
    sign_passes = 2 if (signed and arch.two_cycle_signed) else 1

    # one "pass" = all crossbars of one layer copy process one input vector
    # (toeplitz output positions). The filter dim is parallel hardware.
    passes = math.ceil(layer.n_positions / m.toeplitz_positions)
    total_cols = m.n_segments * layer.n_filters * n_w
    col_passes = passes * total_cols

    converts = col_passes * arch.converts_per_column_pass() * sign_passes
    e_adc = converts * adc_energy_per_convert(arch.adc_bits)

    # DAC drives every occupied row of every crossbar, every cycle
    rows_driven = min(layer.filter_len, arch.rows * m.n_segments) \
        * math.ceil(layer.n_filters / m.filters_per_xbar)
    if layer.depthwise:
        rows_driven = m.rows_used * math.ceil(layer.n_filters / m.filters_per_xbar)
    row_cycles = passes * rows_driven * cycles
    avg_pulses = AVG_INPUT_DENSITY * 15.0  # 4b pulse-train, data-dependent
    e_dac = row_cycles * (E_DAC_STATIC + E_DAC_PULSE * avg_pulses)

    # ReRAM crossbar: every occupied cell integrates input pulses
    wdens = AVG_WEIGHT_DENSITY[arch.encoding]
    # (toeplitz copies multiply occupied cells but divide passes: net equal)
    cells = passes * cycles * layer.filter_len * layer.n_filters * n_w \
        * m.toeplitz_positions
    e_xbar = cells * E_CELL_MAX * AVG_INPUT_DENSITY * wdens
    if arch.spec_slices:  # recovery cycles re-run the crossbar (paper §4.3)
        e_xbar *= 1.25    # recovery cheaper: small 1b inputs

    # buffers: input-slice reads per row-cycle; every ADC convert triggers a
    # shift+add into a 16b psum-buffer entry; outputs requantized to 8b
    out_bytes = layer.n_positions * layer.n_filters
    e_buffer = row_cycles * (2 if arch.spec_slices else 1) * 0.125 * E_SRAM_BYTE \
        + converts * 2 * E_SRAM_BYTE
    # network/eDRAM: inputs travel the H-tree to every crossbar of the copy
    # (span grows with crossbar count), outputs return once
    span = math.sqrt(max(m.n_crossbars, 1))
    in_elems = layer.n_positions * layer.filter_len / max(m.toeplitz_positions, 1)
    e_network = in_elems * (E_EDRAM_BYTE + E_ROUTER_BYTE * 0.1 * span) \
        + out_bytes * (E_EDRAM_BYTE + E_ROUTER_BYTE)

    # digital: shift+add per convert, requant per output, center processing
    # (one add per input element + one mul/sub per filter-segment-pass)
    e_digital = converts * E_DIGITAL_MAC * 0.1 + out_bytes * E_DIGITAL_MAC * 2
    if arch.encoding == "center":
        e_digital += (passes * rows_driven * 0.02
                      + passes * m.n_segments * layer.n_filters) * E_DIGITAL_MAC

    # only output positions serialize (filters/segments are parallel xbars)
    latency = passes * cycles * CYCLE_NS
    cpm = converts / max(layer.macs, 1)
    return LayerReport(layer=layer, mapping=m, converts=converts,
                       converts_per_mac=cpm, e_adc=e_adc, e_dac=e_dac,
                       e_xbar=e_xbar, e_buffer=e_buffer, e_network=e_network,
                       e_digital=e_digital, latency_ns=latency)


def analyze_dnn(arch: PimArchConfig, layers: Sequence[mp.LayerShape],
                replicate: bool = True) -> DnnReport:
    reports = [analyze_layer(arch, l) for l in layers]
    if replicate:
        mapped = [r.mapping for r in reports]
        lat = [r.latency_ns for r in reports]
        new_maps = mp.greedy_replicate(mapped, lat, arch.total_crossbars)
        for r, nm in zip(reports, new_maps):
            r.mapping = nm
    return DnnReport(arch=arch.name, layers=reports)


def titanium_law(energy_per_convert: float, converts_per_mac: float,
                 macs: float, utilization: float) -> float:
    """The Titanium Law, verbatim (Table 2)."""
    return energy_per_convert * converts_per_mac * macs * (1.0 / utilization)


def pim_work_energy_pj(totals: dict, adc_bits: int) -> dict:
    """Price collected serve-time work totals with the component model.

    ``totals`` is a ``repro.models.layers.pim_stats_totals`` dict (host
    ints) from the jitted decode step. This is the live counterpart of
    :func:`analyze_layer`: the ADC term is exact (converts are counted,
    not modeled), the digital term is the same shift+add-per-convert
    coefficient the static model uses, and the crossbar term scales the
    per-cell energy by the counted MACs at the mean input/weight
    densities. Buffer/network energies need mapping information a live
    counter stream does not carry and are omitted — ADC dominance
    (Fig. 1) makes this a tight lower bound.
    """
    converts = float(totals.get("adc_converts", 0))
    macs = float(totals.get("macs", 0))
    e_adc = converts * adc_energy_per_convert(adc_bits)
    e_digital = converts * E_DIGITAL_MAC * 0.1
    e_xbar = macs * E_CELL_MAX * AVG_INPUT_DENSITY \
        * AVG_WEIGHT_DENSITY["center"]
    return {"e_adc_pj": e_adc, "e_digital_pj": e_digital,
            "e_xbar_pj": e_xbar,
            "total_pj": e_adc + e_digital + e_xbar}
