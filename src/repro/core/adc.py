"""ADC model (paper §3, §4.3, §7.2).

RAELLA's ADC captures the 7 least-significant bits of a signed column sum
with a step size of one sliced-product LSB: in-range sums are converted with
*perfect* fidelity; out-of-range sums saturate at [-64, 63]. Saturation at
either bound is detectable (used as the speculation-failure signal).

The analog noise model for the Fig. 15 ablation follows the paper: the
column sum is N(mu, sigma^2) with mu = N+ - N- (ideal signed sum) and
sigma = E * sqrt(N+ + N-), where N+/N- are the positive / negative
sliced-product sums and E is the noise level.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    bits: int = 7
    signed: bool = True
    # Offset of the conversion window (a miscalibrated converter whose
    # code 0 does not sit at analog 0). The crossbar padding contract —
    # zero-padded rows / slice planes are numerically inert — requires a
    # window containing 0 (``check_zero_preserving``); the datapath
    # refuses to run otherwise.
    zero_point: int = 0

    @property
    def lo(self) -> int:
        base = -(1 << (self.bits - 1)) if self.signed else 0
        return base + self.zero_point

    @property
    def hi(self) -> int:
        base = (1 << (self.bits - 1)) - 1 if self.signed \
            else (1 << self.bits) - 1
        return base + self.zero_point

    @property
    def levels(self) -> int:
        return 1 << self.bits

    @property
    def zero_preserving(self) -> bool:
        """Does this ADC map an analog 0 to digital 0 (clip is identity
        at 0)? Padding planes and zero-padded rows rely on this."""
        return self.lo <= 0 <= self.hi


RAELLA_ADC = ADCConfig(bits=7, signed=True)      # [-64, 63]
ISAAC_ADC = ADCConfig(bits=8, signed=False)      # ISAAC: unsigned arithmetic


def check_zero_preserving(cfg: ADCConfig) -> None:
    """Assert the padding invariant: the ADC window must contain 0.

    ``EncodedWeights`` zero-pads segment rows and (for ragged per-site
    plans) whole slice planes; correctness of both the Python datapath
    and the fused kernel requires a zero column sum to convert to 0. An
    ADC whose window excludes 0 (e.g. a non-zero ``zero_point`` pushing
    ``lo`` above 0) silently biases every padded conversion, so refuse
    loudly instead.
    """
    if not cfg.zero_preserving:
        raise ValueError(
            f"ADC window [{cfg.lo}, {cfg.hi}] (bits={cfg.bits}, "
            f"signed={cfg.signed}, zero_point={cfg.zero_point}) does not "
            "contain 0: zero-padded crossbar rows/planes would convert to "
            f"{min(max(0, cfg.lo), cfg.hi)}, breaking the padding contract")


def convert(col_sum: jnp.ndarray,
            cfg: ADCConfig = RAELLA_ADC,
            *,
            noise_level: float = 0.0,
            pos_sum: jnp.ndarray | None = None,
            neg_sum: jnp.ndarray | None = None,
            key: jax.Array | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Convert analog column sums to digital. Returns (value, saturated).

    value: int32 clipped to [cfg.lo, cfg.hi]; saturated: bool — output equals
    either bound (the paper's detection rule; exact-at-bound values flag as
    failures too, which is faithful).
    """
    check_zero_preserving(cfg)
    x = col_sum.astype(jnp.float32)
    if noise_level and key is not None:
        if pos_sum is None or neg_sum is None:
            raise ValueError("noise model needs pos/neg sliced-product sums")
        sigma = noise_level * jnp.sqrt((pos_sum + neg_sum).astype(jnp.float32))
        x = x + sigma * jax.random.normal(key, x.shape, dtype=jnp.float32)
    q = jnp.round(x).astype(jnp.int32)
    out = jnp.clip(q, cfg.lo, cfg.hi)
    saturated = (out == cfg.lo) | (out == cfg.hi)
    return out, saturated


def required_bits(col_sum: jnp.ndarray, signed: bool = True) -> jnp.ndarray:
    """Resolution (bits) needed to represent each column sum exactly."""
    mag = jnp.abs(col_sum).astype(jnp.int32)
    bits = jnp.ceil(jnp.log2(jnp.maximum(mag, 1).astype(jnp.float32) + 1.0))
    return bits.astype(jnp.int32) + (1 if signed else 0)
