"""Functional 512-row 2T2R crossbar simulator (paper §4.1.4, §5.1).

Bit-exact integer model of RAELLA's analog datapath:

  inputs (unsigned 8b, temporally sliced)  x  weights (Center+Offset encoded,
  spatially sliced, signed sign-magnitude planes)  ->  per-(input-slice,
  weight-slice) signed column sums over <=512 rows  ->  ADC (7b clamp, with
  optional analog noise)  ->  digital shift+add  ->  int32 psums
  (+ the digital center term phi * sum(I)).

Everything is jit-able jnp; the Pallas kernel in repro.kernels.sliced_crossbar
implements the same contraction for TPU and is verified against
``repro.kernels.ref`` which calls into this module.

Signed inputs are handled the paper's way: two passes over max(x, 0) and
max(-x, 0) (see pim_linear), which also generates the input bit sparsity the
paper exploits.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import adc as adc_lib
from repro.core import backends as bk
from repro.core import center_offset as co
from repro.core import slicing as sl


@dataclasses.dataclass
class CrossbarStats:
    """Fidelity / work counters for one forward pass (python-side, jit-safe)."""
    adc_converts: int                # ADC conversions performed (exact Python int)
    saturations: jnp.ndarray         # scalar int — saturated conversions
    conversions_possible: int        # converts a no-spec design needs
    macs: int                        # logical 8b MACs computed


def work_dtype() -> jnp.dtype:
    """Accumulator dtype for data-dependent work counters.

    Shape-static counters (converts, attempts, MACs) are exact Python
    ints, immune to overflow. Traced accumulations (saturation / failure
    counts) use int64 when ``jax_enable_x64`` is on; otherwise jnp would
    *silently* downcast an explicit int64 back to int32, so int32 is the
    honest ceiling there.
    """
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def _segment_inputs(x_u8: jnp.ndarray, n_seg: int, rows_per_xbar: int) -> jnp.ndarray:
    """(..., rows) -> (..., n_seg, rows_per_xbar) zero-padded."""
    pad = n_seg * rows_per_xbar - x_u8.shape[-1]
    if pad < 0:
        raise ValueError(
            f"input rows {x_u8.shape[-1]} exceed the crossbar capacity "
            f"{n_seg} segments x {rows_per_xbar} rows = "
            f"{n_seg * rows_per_xbar}: the encoding was built for fewer "
            "rows than this input carries (shape mismatch between x and "
            "the EncodedWeights it is paired with)")
    xp = jnp.pad(x_u8.astype(jnp.int32), [(0, 0)] * (x_u8.ndim - 1) + [(0, pad)])
    return xp.reshape(x_u8.shape[:-1] + (n_seg, rows_per_xbar))


def column_sums(x_slice: jnp.ndarray, plane: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Signed column sums of one (input-slice, weight-slice) pair.

    x_slice: (B, n_seg, R) int32 unsigned slice values.
    plane:   (n_seg, R, C) int8 signed slice values.
    Returns (pos, neg) int32 of shape (B, n_seg, C): positive / negative
    sliced-product sums (their difference is the ideal column sum; both are
    needed for the noise model and 2T2R energy accounting).
    """
    p = plane.astype(jnp.int32)
    pos = jnp.einsum("bsr,src->bsc", x_slice, jnp.maximum(p, 0),
                     preferred_element_type=jnp.int32)
    neg = jnp.einsum("bsr,src->bsc", x_slice, jnp.maximum(-p, 0),
                     preferred_element_type=jnp.int32)
    return pos, neg


def forward(x_u8: jnp.ndarray,
            enc: co.EncodedWeights,
            input_slicing: Sequence[int] = (1,) * 8,
            adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC,
            *,
            noise_level: float = 0.0,
            key: jax.Array | None = None,
            ideal: bool = False,
            backend: str | None = None,
            device: bk.CrossbarBackend | None = None
            ) -> tuple[jnp.ndarray, CrossbarStats]:
    """Full-fidelity-path crossbar forward (static input slicing, no speculation).

    x_u8: (B, rows) unsigned 8b inputs. Returns (psum int32 (B, cols), stats).
    ``ideal=True`` skips the ADC entirely (infinite-resolution reference).

    At noise 0 the whole datapath runs as ONE fused kernel op
    (``repro.kernels.ops.fused_crossbar_forward``: in-kernel input
    slicing, per-segment ADC clamp, shift+add, center term, saturation
    count) — bit-exact vs the loop below, since in-range column sums are
    far below 2^24 so ``adc.convert``'s float32 round is the identity on
    them. ``backend`` picks the kernel backend per the registry rules
    ('xla' / 'interpret' / 'pallas-tpu' / 'auto', env-overridable);
    ``backend='python'`` forces the reference loop (the oracle the
    differential tests compare against). Noisy or ideal runs always use
    the loop.

    ``enc`` may carry *padded* slice planes (per-site compiled plans pad the
    slice axis to a common max): all-zero padding planes convert to 0 at the
    signed ADC and contribute nothing, so both paths are correct without
    a mask; ``enc.shifts`` may then be a traced int32 array rather than a
    static tuple (the shift applied to a zero value is irrelevant). The
    work *stats*, however, count every plane — convert counts are only
    meaningful for unpadded encodings (the energy/accounting harnesses all
    build those); use ``repro.models.pim_compile.CompiledPim.report`` for
    per-site convert pricing of padded plans.

    ``device`` selects the analog array model (``repro.core.backends``):
    ``None`` / ``IdealSim`` is the exact integer 2T2R read (fused-kernel
    eligible); a ``NonidealSim`` programs the planes once per call with
    its die's ReRAM nonidealities (program noise, drift, stuck-ats, IR
    drop) and reads analog (float32) column sums — at an all-zero corner
    this is bit-exact with the ideal path. Work *stats* are identical for
    every device: nonidealities change values, never the convert counts.
    """
    B = x_u8.shape[0]
    n_seg, R = enc.n_segments, enc.rows_per_xbar
    in_bounds = sl.slice_bounds(input_slicing, sl.INPUT_BITS)
    planes = jnp.asarray(enc.planes)  # (n_w, n_seg, R, C)
    dev = device if device is not None else bk.IDEAL

    if not ideal:
        adc_lib.check_zero_preserving(adc)  # the padding contract
    if noise_level and key is None:
        raise ValueError(
            f"noise_level={noise_level} requires a PRNG key: pass key= "
            "(silently running noiseless would drop the requested noise)")
    if not ideal and noise_level == 0.0 and backend != "python" \
            and isinstance(dev, bk.IdealSim):
        from repro.kernels import ops as kops
        psum, sats = kops.fused_crossbar_forward(
            x_u8, planes, enc.shifts, jnp.asarray(enc.centers),
            input_slicing=tuple(int(b) for b in input_slicing),
            adc_lo=adc.lo, adc_hi=adc.hi, rows_per_xbar=R, backend=backend)
        # shape-static counters stay exact Python ints: B * seg * cols *
        # slices * slices overflows int32 at production scales
        total = B * n_seg * enc.cols * len(in_bounds) * enc.n_slices
        stats = CrossbarStats(
            adc_converts=total,
            saturations=sats.astype(work_dtype()),
            conversions_possible=total,
            macs=B * enc.rows * enc.cols)
        return psum, stats

    xs = _segment_inputs(x_u8, n_seg, R)  # (B, n_seg, R)
    prog = dev.program(planes, rows=enc.rows)

    psum = co.center_term(x_u8, enc)  # (B, C) int32 — digital center term
    total_converts = 0
    saturations = jnp.zeros((), work_dtype())
    n_keys = len(in_bounds) * enc.n_slices
    keys = (jax.random.split(key, n_keys) if key is not None else [None] * n_keys)
    ki = 0
    for (hi, li) in in_bounds:
        x_sl = sl.crop_unsigned(xs, hi, li)  # (B, n_seg, R)
        for j in range(enc.n_slices):
            lw = enc.shifts[j]
            pos, neg = dev.read(prog, x_sl, j)
            cs = pos - neg
            if ideal:
                val = cs if jnp.issubdtype(cs.dtype, jnp.integer) \
                    else jnp.round(cs).astype(jnp.int32)
            else:
                val, sat = adc_lib.convert(
                    cs, adc, noise_level=noise_level,
                    pos_sum=pos, neg_sum=neg, key=keys[ki])
                saturations = saturations + sat.sum(dtype=work_dtype())
            ki += 1
            psum = psum + (val.sum(axis=1) << (li + lw))
            total_converts += B * n_seg * enc.cols
    stats = CrossbarStats(
        adc_converts=total_converts,
        saturations=saturations,
        conversions_possible=total_converts,
        macs=B * enc.rows * enc.cols)
    return psum, stats


def matmul_reference(x_u8: jnp.ndarray, w_u8: jnp.ndarray) -> jnp.ndarray:
    """Ideal integer matmul in the unsigned-weight domain: x @ w, int32."""
    return jnp.einsum("br,rc->bc", x_u8.astype(jnp.int32), w_u8.astype(jnp.int32),
                      preferred_element_type=jnp.int32)


def column_sum_distribution(x_u8: jnp.ndarray,
                            enc: co.EncodedWeights,
                            input_slicing: Sequence[int],
                            adc: adc_lib.ADCConfig = adc_lib.RAELLA_ADC):
    """All raw (pre-ADC) column sums + fraction in ADC range (Fig. 3 harness)."""
    n_seg, R = enc.n_segments, enc.rows_per_xbar
    xs = _segment_inputs(x_u8, n_seg, R)
    planes = jnp.asarray(enc.planes)
    sums = []
    for (hi, li) in sl.slice_bounds(input_slicing, sl.INPUT_BITS):
        x_sl = sl.crop_unsigned(xs, hi, li)
        for j in range(enc.n_slices):
            pos, neg = column_sums(x_sl, planes[j])
            sums.append((pos - neg).reshape(-1))
    cs = jnp.concatenate(sums)
    in_range = jnp.mean((cs >= adc.lo) & (cs <= adc.hi))
    return cs, in_range
