"""chameleon-34b [arXiv:2405.09818] — early-fusion VLM backbone.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
tokens in one vocabulary). QK-norm as in the paper. The VQ image tokenizer
is a STUB per the assignment: input_specs() feeds the fused token stream.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, qk_norm=True,
    micro_batches=2,
)
