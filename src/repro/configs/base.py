"""Architecture config schema + the four assigned input shapes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free architectures
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # mixture-of-experts
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1          # MoE FFN on layers with idx % moe_every == 0
    capacity_factor: float = 1.25
    # attention details
    qkv_bias: bool = False
    causal: bool = True
    head_dim: int = 0           # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qk_norm: bool = False       # chameleon-style query/key norm
    # block structure: repeating pattern of block kinds
    block_pattern: tuple[str, ...] = ("attn",)   # attn | mamba | rwkv
    # frontend
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm STUB frontends)
    # ssm details
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    rwkv_head_dim: int = 64
    # numerics / runtime
    norm_eps: float = 1e-5
    activation: str = "silu"    # silu | gelu
    dtype: str = "bfloat16"
    remat: bool = True
    opt_state_dtype: str = "float32"   # bf16 for >=100B models (fits HBM)
    micro_batches: int = 1             # gradient-accumulation microbatches
    kv_cache_dtype: str = "bfloat16"   # "int8": RAELLA-style low-precision
                                       # cache storage w/ digital scales
    # PIM integration: "off" (bf16), "fast" (centered int8 serving path),
    # "exact" (bit-exact accelerator simulation; small models only),
    # "int8" (ideal 8b-quantized reference — the dequant oracle the exact
    # path must match bit-for-bit at noise 0 / non-saturating ADC).
    # Consumed by repro.models (pim_matmul) and both serve engines; plans
    # come from repro.models.pim.prepare_pim_params.
    pim_mode: str = "off"
    pim_use_pallas: bool = False       # fast path: Pallas kernel vs XLA ref
    # Kernel backend for the repro.kernels.ops registry (fused exact
    # datapath + fast-path matmul): "auto" picks pallas-tpu on TPU and
    # the XLA reference elsewhere; "interpret" forces the Pallas
    # interpreter (bit-identical, slow — CI's kernel leg); "python"
    # forces the crossbar reference loop (exact mode only). The
    # REPRO_KERNEL_BACKEND env var overrides this at dispatch time.
    pim_kernel_backend: str = "auto"
    # Weight slicing fed to the compile step (repro.models.pim_compile):
    # a tuple pins every projection site to that slicing; "adaptive" runs
    # the paper's Algorithm 1 per site (per repeat-layer, per MoE expert,
    # conservative 1b-per-slice lm_head). The compiled plan — not this
    # knob — is what the dispatch path consumes.
    pim_weight_slicing: tuple[int, ...] | str = (4, 2, 2)
    pim_speculation: bool = True       # exact path: dynamic input slicing
    pim_adc_bits: int = 24             # exact path ADC; 24b = lossless
                                       # (contract default), 7 = paper ADC
    pim_search_adc_bits: int = 7       # ADC assumed by the Algorithm-1
                                       # search (paper: the real 7b ADC,
                                       # independent of pim_adc_bits)
    # Analog array model for the exact path (repro.core.backends):
    # "ideal" is the exact integer 2T2R read (fused-kernel eligible);
    # "nonideal" programs every crossbar with the ReRAM nonidealities of
    # the named pim_device_corner (conductance program noise, retention
    # drift, stuck-at fault maps, IR drop), deterministic in
    # pim_device_seed — the "does this plan survive a 3-sigma die?"
    # sweep axis. serve.py exposes it as --device-corner.
    pim_crossbar_backend: str = "ideal"
    pim_device_corner: str = "nominal"  # nominal | 1sigma | 3sigma
    pim_device_seed: int = 0            # die seed (fault maps, write noise)

    def __post_init__(self):
        if self.n_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not divisible by "
                f"pattern length {len(self.block_pattern)}")
        ws = self.pim_weight_slicing
        if isinstance(ws, str):
            if ws != "adaptive":
                raise ValueError(
                    f"{self.name}: pim_weight_slicing must be a slice-width "
                    f"tuple or 'adaptive', got {ws!r}")
        elif sum(ws) != 8 or any(not 1 <= b <= 4 for b in ws):
            raise ValueError(
                f"{self.name}: pim_weight_slicing {ws!r} must cover 8 weight "
                "bits with 1..4b slices (paper: <=4b ReRAM devices)")
        allowed = ("auto", "xla", "interpret", "pallas", "pallas-tpu",
                   "pallas-gpu", "python")
        if self.pim_kernel_backend not in allowed:
            raise ValueError(
                f"{self.name}: pim_kernel_backend "
                f"{self.pim_kernel_backend!r} not in {allowed}")
        if self.pim_crossbar_backend not in ("ideal", "nonideal"):
            raise ValueError(
                f"{self.name}: pim_crossbar_backend "
                f"{self.pim_crossbar_backend!r} not in ('ideal', 'nonideal')")
        corners = ("nominal", "1sigma", "3sigma")  # repro.core.backends.CORNERS
        if self.pim_device_corner not in corners:
            raise ValueError(
                f"{self.name}: pim_device_corner "
                f"{self.pim_device_corner!r} not in {corners}")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def moe_layer(self, pattern_idx: int) -> bool:
        """Is the FFN at this pattern position a MoE FFN?"""
        return self.is_moe and (pattern_idx % self.moe_every == 0)

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = len(self.block_pattern)
        small = dict(
            name=self.name + "-smoke",
            n_layers=pat if self.n_layers >= pat else self.n_layers,
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads else 0,
            d_ff=128,
            vocab_size=min(self.vocab_size, 256),
            n_experts=min(self.n_experts, 4),
            head_dim=16 if self.n_heads else 0,
            mamba_d_state=8,
            rwkv_head_dim=16,
            remat=False,
            micro_batches=1,
            capacity_factor=4.0,  # no MoE token drops at smoke scale, so
                                  # forward == prefill+decode exactly
        )
        if self.n_heads and small["n_heads"] % max(small["n_kv_heads"], 1):
            small["n_kv_heads"] = 1
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def active_param_count(self) -> int:
        """Params touched per token: MoE counts only top-k experts."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        d, f = self.d_model, self.d_ff
        moe_positions = sum(1 for i in range(len(self.block_pattern))
                            if self.moe_layer(i))
        expert_params = self.n_repeats * moe_positions \
            * self.n_experts * 3 * d * f
        active = expert_params * self.experts_per_token / self.n_experts
        return int(total - expert_params + active)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if self.input_mode == "tokens":
            total += v * d  # untied LM head
        else:
            total += v * d  # output head only (inputs are embeddings)
        for i, kind in enumerate(self.block_pattern):
            n = self.n_repeats
            if kind == "attn":
                attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
                    + hd * self.n_heads * d
                total += n * (attn + 2 * d)  # + norms
            elif kind == "mamba":
                di = self.mamba_expand * d
                total += n * (2 * d * di + di * self.mamba_conv
                              + di * (2 * self.mamba_d_state + d // 16 + 1)
                              + (d // 16) * di + di * d + d)
            elif kind == "rwkv":
                # 5 square projections + decay LoRA + channel-mix (2 mats
                # + receptance gate)
                total += n * (5 * d * d + 2 * d * 64 + 2 * d
                              + 2 * d * f + d * d)
            if kind in ("attn", "mamba"):
                if self.moe_layer(i):
                    total += n * (d * self.n_experts  # router
                                  + self.n_experts * 3 * d * f)
                elif kind != "mamba" or self.family == "hybrid":
                    total += n * 3 * d * f
        return total


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
