"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    n_experts=16, experts_per_token=2, moe_every=1,
    opt_state_dtype="float32",
    micro_batches=2,
)
