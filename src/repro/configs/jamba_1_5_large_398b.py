"""jamba-1.5-large-398b [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2,
Mamba:attention 1:7 interleave (one attention layer per 8), MoE every
other layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    n_experts=16, experts_per_token=2, moe_every=2,
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    opt_state_dtype="bfloat16",  # 398B
    micro_batches=16,
)
