"""Assigned architecture registry: --arch <id> resolves here."""

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ArchConfig,
    InputShape,
)
from repro.configs.phi3_5_moe_42b import CONFIG as PHI35_MOE
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_15_LARGE
from repro.configs.qwen1_5_110b import CONFIG as QWEN15_110B
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.qwen2_5_32b import CONFIG as QWEN25_32B
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN15_05B
from repro.configs.hubert_xlarge import CONFIG as HUBERT_XLARGE
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.chameleon_34b import CONFIG as CHAMELEON_34B
from repro.configs.raella_bert_large import CONFIG as RAELLA_BERT_LARGE

__all__ = [
    "ALL_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "TRAIN_4K",
    "ArchConfig", "InputShape", "REGISTRY", "ASSIGNED", "get",
    "runnable_shapes",
]

REGISTRY: dict[str, ArchConfig] = {
    c.name: c for c in [
        PHI35_MOE, LLAMA4_MAVERICK, JAMBA_15_LARGE, QWEN15_110B, YI_6B,
        QWEN25_32B, QWEN15_05B, HUBERT_XLARGE, RWKV6_3B, CHAMELEON_34B,
        RAELLA_BERT_LARGE,
    ]
}

ASSIGNED = tuple(n for n in REGISTRY if n != "raella-bert-large")


def get(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def runnable_shapes(cfg: ArchConfig) -> tuple[InputShape, ...]:
    """Assignment skip rules (see DESIGN.md §4):
    - encoder-only archs have no decode step -> skip decode shapes;
    - long_500k requires sub-quadratic attention -> SSM/hybrid only."""
    shapes = []
    for s in ALL_SHAPES:
        if s.kind == "decode" and not cfg.causal:
            continue  # encoder-only: no autoregressive step
        if s.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            continue  # pure full-attention archs skip 500k decode
        shapes.append(s)
    return tuple(shapes)
