"""hubert-xlarge [arXiv:2106.07447] — encoder-only audio transformer.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit targets).
The conv waveform frontend is a STUB per the assignment: input_specs()
feeds precomputed frame embeddings (B, S, 1280).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False, input_mode="embeddings", activation="gelu",
)
