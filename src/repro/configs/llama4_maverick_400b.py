"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4; early fusion].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1, interleaved every other layer (the real Maverick alternates dense /
MoE FFNs; this also lands the 400B total parameter count).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    n_experts=128, experts_per_token=1, moe_every=2,
    block_pattern=("attn", "attn"),  # even layers MoE, odd layers dense
    opt_state_dtype="bfloat16",  # 400B: fp32 m/v does not fit one pod
    micro_batches=16,
)
