"""BERT-Large — the RAELLA paper's own transformer workload (§6.2).

24L d_model=1024 16H d_ff=4096, encoder-only, GELU (signed activations ->
the paper's two-cycle input processing). Feedforward layers are the part
the paper accelerates; this config drives the fig12/table4 benchmarks.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="raella-bert-large", family="audio",  # encoder-only family
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=30522,
    causal=False, activation="gelu",
)
