"""Per-site PIM architecture compiler (the paper's Algorithm 1, per site).

RAELLA "adapts the architecture to each DNN": Algorithm 1 (§4.2) picks a
weight slicing *per layer* by measuring quantization error on calibration
inputs. This module is that compile step for whole LMs — one *projection
site* per weight-static matmul instance (per pattern position, per repeat,
per MoE expert, plus the LM head), each with its own slicing decision:

1. *capture* — an eager, unrolled float forward over the calibration
   tokens with ``PimTap`` recorders standing in for plan leaves, so each
   site is calibrated on exactly the activations the real forward feeds it;
2. *plan* — with ``cfg.pim_weight_slicing == "adaptive"``,
   ``core.adaptive.find_best_slicing`` runs per site under the paper's
   search ADC (``cfg.pim_search_adc_bits``, default the real 7b ADC), with
   the last-layer conservative 1b-per-slice override for ``lm_head``; a
   tuple pins every site to that slicing (the pre-compiler behavior);
3. *prepare* — for ``fast``/``int8``, ``quant.calibrate_layer`` +
   ``quant.quantize_weights_centered`` vmapped over all site instances at
   once; for ``exact``, instances are grouped by chosen slicing and each
   group is Center+Offset encoded in a single ``co.encode`` call with the
   instances folded into the column axis (Eq. 2 centers are per-column, so
   this is exact) — compile work scales with distinct (shape, slicing)
   groups, not with layer count.

Because chosen slicings are ragged across the instances stacked into one
scan/vmap leaf, exact-mode planes are padded to the site's max slice count:
``slice_shifts`` (int32) carries each instance's recombination shifts and
``slice_valid`` masks the padding (padded planes are zeroed; a zero plane
converts to 0 at the signed ADC, so padding is a numerical no-op).

The result is a :class:`CompiledPim`: the plan pytree + sharding specs the
serve engines consume, and a :class:`SitePlan` table (chosen slicing,
measured §4.2.1 error, search ADC bits) whose :meth:`CompiledPim.report`
prices every site with the §2.5 Titanium-Law energy model (converts/MAC,
ADC energy share, slice-count histogram) — see ``benchmarks/compile_report``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import adaptive as ad
from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import energy as en
from repro.core import mapping as mp
from repro.core import slicing as slc
from repro.models import layers as L
from repro.models import transformer as T
from repro.quant import quantize as q

_CORE_PROJ = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mamba": ("in_proj", "x_proj", "out_proj"),
}
_FFN_PROJ = ("w1", "w3", "w2")

SEARCH_ROWS = 16  # calibration rows fed to Algorithm 1 (paper: ~10 inputs)
CONSERVATIVE_SLICING = (1,) * slc.WEIGHT_BITS


# ------------------------------------------------------------------ site table
@dataclasses.dataclass(frozen=True)
class SitePlan:
    """One projection-site instance's compiled architecture decision."""
    site: str                  # e.g. "blocks[0].core.wq[r1]", "embed.head"
    d_in: int
    d_out: int
    slicing: tuple[int, ...]
    error: float | None        # measured §4.2.1 error (None: pinned slicing)
    search_adc_bits: int
    last_layer: bool = False

    @property
    def n_slices(self) -> int:
        return len(self.slicing)


@dataclasses.dataclass
class CompiledPim:
    """Plan pytree + specs + the per-site architecture table."""
    cfg: ArchConfig
    plans: dict
    specs: dict
    sites: tuple[SitePlan, ...]

    def site(self, name: str) -> SitePlan:
        for s in self.sites:
            if s.site == name:
                return s
        raise KeyError(name)

    def distinct_slicings(self) -> tuple[tuple[int, ...], ...]:
        return tuple(sorted({s.slicing for s in self.sites}))

    def slice_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for s in self.sites:
            hist[s.n_slices] = hist.get(s.n_slices, 0) + 1
        return dict(sorted(hist.items()))

    def report(self, tokens: int = 4096) -> dict:
        """Price every site with the §2.5 energy model (JSON-serializable).

        Each site is mapped onto RAELLA silicon with *its own* slice count
        (``bits_per_weight_slice = 8 / n_slices``); speculation follows
        ``cfg.pim_speculation``. Reported per site: converts/MAC, ADC
        energy, total energy, ADC share — plus whole-model aggregates and
        the slice-count histogram (the paper's Fig. 7 x Fig. 12 story).
        """
        spec = 3 if self.cfg.pim_speculation else 0
        rows = []
        tot_converts = tot_macs = 0.0
        tot_adc = tot_energy = 0.0
        for sp in self.sites:
            shape = mp.LayerShape(
                name=sp.site, filter_len=sp.d_in, n_filters=sp.d_out,
                n_positions=tokens, signed_inputs=True,
                last_layer=sp.last_layer, row_positions=tokens)
            arch = dataclasses.replace(
                en.RAELLA, name=f"raella-{sp.n_slices}s",
                n_weight_slices=sp.n_slices,
                bits_per_weight_slice=slc.WEIGHT_BITS / sp.n_slices,
                spec_slices=spec, adaptive_slicing=False)
            r = en.analyze_layer(arch, shape)
            rows.append({
                "site": sp.site,
                "slicing": list(sp.slicing),
                "n_slices": sp.n_slices,
                "error": None if sp.error is None else round(sp.error, 4),
                "converts_per_mac": round(r.converts_per_mac, 4),
                "adc_energy_pj": round(r.e_adc, 1),
                "energy_pj": round(r.energy, 1),
                "adc_share": round(r.e_adc / r.energy, 3),
            })
            tot_converts += r.converts
            tot_macs += shape.macs
            tot_adc += r.e_adc
            tot_energy += r.energy
        return {
            "arch": self.cfg.name,
            "pim_mode": self.cfg.pim_mode,
            "slicing": ("adaptive"
                        if self.cfg.pim_weight_slicing == "adaptive"
                        else list(self.cfg.pim_weight_slicing)),
            "n_sites": len(self.sites),
            "distinct_slicings": ["-".join(map(str, s))
                                  for s in self.distinct_slicings()],
            "slice_histogram": {str(k): v
                                for k, v in self.slice_histogram().items()},
            "converts_per_mac": round(tot_converts / max(tot_macs, 1), 4),
            "adc_energy_share": round(tot_adc / max(tot_energy, 1e-9), 3),
            "energy_uj": round(tot_energy / 1e6, 2),
            "sites": rows,
        }


# ------------------------------------------------------------------ capture
def _block_projections(cfg: ArchConfig, i: int) -> dict | None:
    """Weight-static projection names for pattern position ``i`` (grouped
    by param subtree), or None for rwkv (float path)."""
    kind = cfg.block_pattern[i]
    if kind not in _CORE_PROJ:
        return None
    return {"core": _CORE_PROJ[kind], "ffn": _FFN_PROJ}


def _build_taps(cfg: ArchConfig) -> dict:
    blocks = []
    for i in range(len(cfg.block_pattern)):
        paths = _block_projections(cfg, i)
        if paths is None:
            blocks.append(None)
            continue
        blocks.append({g: {n: L.PimTap() for n in names}
                       for g, names in paths.items()})
    return {"embed": {"head": L.PimTap()}, "blocks": blocks}


def _capture(params: dict, cfg: ArchConfig, calib_tokens, taps: dict) -> None:
    """Eager float forward that feeds every tap its projection inputs.

    Unrolled over repeats (no ``lax.scan``) so the taps see concrete
    per-repeat values rather than tracers.
    """
    x = T.embed_inputs(params, cfg, jnp.asarray(calib_tokens))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    for r in range(cfg.n_repeats):
        for i, kind in enumerate(cfg.block_pattern):
            bp = jax.tree.map(lambda a, _r=r: a[_r], params["blocks"][i])
            x = T._apply_block(kind, i, bp, cfg, x, positions,
                               plan=taps["blocks"][i])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    L.lm_head(params["embed"], cfg, x, plan=taps["embed"]["head"])


# ------------------------------------------------------------------ slicing
def _site_slicings(wf: jnp.ndarray, xf: jnp.ndarray, cfg: ArchConfig,
                   last_layer: bool) -> tuple[list, list]:
    """Per-instance (slicing, error) for one site's flattened stack.

    wf: (K, d_in, d_out); xf: (K, N, d_in). Adaptive mode runs Algorithm 1
    per instance on a row subsample under the search ADC; tuple mode pins
    every instance (error None — nothing was measured).
    """
    K = wf.shape[0]
    if cfg.pim_weight_slicing != "adaptive":
        s = tuple(cfg.pim_weight_slicing)
        return [s] * K, [None] * K
    adc = adc_lib.ADCConfig(bits=cfg.pim_search_adc_bits, signed=True)
    slicings, errors = [], []
    for k in range(K):
        choice = ad.find_best_slicing(
            wf[k], xf[k][:SEARCH_ROWS], adc=adc, last_layer=last_layer)
        slicings.append(choice.slicing)
        errors.append(choice.error)
    return slicings, errors


# ------------------------------------------------------------------ prepare
def _fast_prepare_2d(w: jnp.ndarray, x_cal: jnp.ndarray) -> dict:
    """One layer's fast-path plan: symmetric per-channel int8 (the
    reference quantizer) + centered asymmetric int8 (Eq. 1 operands)."""
    w = w.astype(jnp.float32)
    lq, w_q = q.calibrate_layer(w, x_cal, signed_inputs=True)
    w_off, centers, scale = q.quantize_weights_centered(w)
    return {"w_off": w_off, "centers": centers, "scale": scale,
            "w_q": w_q, "w_scale": lq.w_scale, "x_scale": lq.x_scale}


def _ref_quant_2d(w: jnp.ndarray, x_cal: jnp.ndarray) -> dict:
    """Exact-mode reference quantization (the jax-traceable part)."""
    lq, w_q = q.calibrate_layer(w, x_cal, signed_inputs=True)
    return {"w_q": w_q, "w_scale": lq.w_scale, "x_scale": lq.x_scale}


def _exact_prepare_stacked(wf: jnp.ndarray, xf: jnp.ndarray,
                           slicings: list) -> dict:
    """Exact-mode plan leaves for one site's flattened stack.

    wf: (K, R, C) float; xf: (K, N, R); slicings: K tuples, possibly
    ragged. Instances are grouped by slicing; each group's Center+Offset
    encode folds the group into the column axis so the numpy Eq. 2 center
    search runs once per group (per-column centers make this exact), then
    planes are padded to the site's max slice count with ``slice_valid``
    masks and ``slice_shifts`` recombination shifts.
    """
    K, R, C = wf.shape
    qd = jax.vmap(_ref_quant_2d)(wf, xf)  # one trace for all instances
    w_u = np.asarray(qd["w_q"], np.int64) + 128  # unsigned crossbar domain
    n_max = max(len(s) for s in slicings)
    n_seg = -(-R // co.ROWS_PER_CROSSBAR)
    rx = co.ROWS_PER_CROSSBAR
    planes = np.zeros((K, n_max, n_seg, rx, C), np.int8)
    centers = np.zeros((K, n_seg, C), np.int32)
    shifts = np.zeros((K, n_max), np.int32)
    valid = np.zeros((K, n_max), bool)
    groups: dict[tuple, list[int]] = {}
    for k, s in enumerate(slicings):
        groups.setdefault(tuple(s), []).append(k)
    for s, ks in groups.items():
        kg = len(ks)
        folded = np.moveaxis(w_u[ks], 0, 1).reshape(R, kg * C)
        enc = co.encode(folded, s)
        n_s = len(s)
        pl = np.asarray(enc.planes).reshape(n_s, n_seg, rx, kg, C)
        ce = np.asarray(enc.centers).reshape(n_seg, kg, C)
        for j, k in enumerate(ks):
            planes[k, :n_s] = pl[:, :, :, j]
            centers[k] = ce[:, j]
            shifts[k, :n_s] = enc.shifts
            valid[k, :n_s] = True
    return {"planes": jnp.asarray(planes),
            "enc_centers": jnp.asarray(centers),
            "slice_shifts": jnp.asarray(shifts),
            "slice_valid": jnp.asarray(valid),
            "w_q": qd["w_q"], "w_scale": qd["w_scale"],
            "x_scale": qd["x_scale"]}


def _compile_site(name: str, w, x_cal, cfg: ArchConfig, stack_dims: int,
                  last_layer: bool = False) -> tuple[dict, list[SitePlan]]:
    """Compile one projection site. ``stack_dims`` leading axes of ``w``
    and ``x_cal`` are instance axes (0: lm_head, 1: repeats, 2: repeats x
    experts); every instance gets its own Algorithm-1 decision."""
    w = jnp.asarray(w, jnp.float32)
    x_cal = jnp.asarray(x_cal, jnp.float32)
    lead = w.shape[:stack_dims]
    K = int(np.prod(lead)) if stack_dims else 1
    wf = w.reshape((K,) + w.shape[stack_dims:])
    xf = x_cal.reshape((K,) + x_cal.shape[stack_dims:])
    slicings, errors = _site_slicings(wf, xf, cfg, last_layer)
    d_in, d_out = int(wf.shape[1]), int(wf.shape[2])
    sites = []
    for k, idx in enumerate(np.ndindex(*lead) if stack_dims else [()]):
        tag = ""
        if stack_dims:
            parts = [f"r{idx[0]}"] + [f"e{i}" for i in idx[1:]]
            tag = "[" + ",".join(parts) + "]"
        sites.append(SitePlan(
            site=name + tag, d_in=d_in, d_out=d_out,
            slicing=tuple(slicings[k]),
            error=None if errors[k] is None else float(errors[k]),
            search_adc_bits=cfg.pim_search_adc_bits, last_layer=last_layer))
    if cfg.pim_mode in ("fast", "int8"):
        leaf = jax.vmap(_fast_prepare_2d)(wf, xf)
    else:
        leaf = _exact_prepare_stacked(wf, xf, slicings)
    leaf = jax.tree.map(lambda a: a.reshape(lead + a.shape[1:]), leaf)
    return leaf, sites


# ------------------------------------------------------------------ compile
def compile_pim_params(params: dict, cfg: ArchConfig,
                       calib_tokens) -> CompiledPim | None:
    """Compile ``params`` into per-site PIM plans for ``cfg.pim_mode``.

    calib_tokens: (B, S) int32 token ids (or (B, S, D) embeds for
    embedding-mode archs) used for activation-range calibration and the
    adaptive-slicing search. Returns a :class:`CompiledPim`; mode 'off'
    returns ``None`` — the float path needs no compile step.
    """
    if cfg.pim_mode == "off":
        return None
    if cfg.pim_mode not in ("fast", "exact", "int8"):
        raise ValueError(f"unknown pim_mode {cfg.pim_mode!r}")
    taps = _build_taps(cfg)
    _capture(params, cfg, calib_tokens, taps)

    sites: list[SitePlan] = []
    blocks = []
    for i in range(len(cfg.block_pattern)):
        paths = _block_projections(cfg, i)
        if paths is None:
            blocks.append(None)
            continue
        bplan: dict = {}
        for group, names in paths.items():
            expert = group == "ffn" and cfg.moe_layer(i)
            bplan[group] = {}
            for name in names:
                tap = taps["blocks"][i][group][name]
                x_cal = np.stack(tap.x)  # (n_repeats, [E,] N, d_in)
                leaf, leaf_sites = _compile_site(
                    f"blocks[{i}].{group}.{name}",
                    params["blocks"][i][group][name], x_cal, cfg,
                    stack_dims=2 if expert else 1)
                bplan[group][name] = leaf
                sites.extend(leaf_sites)
        blocks.append(bplan)
    head, head_sites = _compile_site(
        "embed.head", params["embed"]["head"], taps["embed"]["head"].x[0],
        cfg, stack_dims=0, last_layer=True)
    sites.extend(head_sites)
    plans = {"embed": {"head": head}, "blocks": blocks}
    return CompiledPim(cfg=cfg, plans=plans, specs=plan_specs(cfg),
                       sites=tuple(sites))


# ------------------------------------------------------------------ specs
def _site_specs(ws: tuple, mode: str) -> dict:
    """Plan-leaf logical axes derived from one weight's spec tuple.

    ``ws`` ends with (in_axis, out_axis); leading entries are stack axes
    (repeat ``None`` and/or ``experts``). The int8 offset planes keep the
    float weight's layout; per-column terms keep the output axis; the
    per-site slice tables (shifts/validity masks) are replicated along the
    padded slice axis.
    """
    lead, out_ax = ws[:-2], ws[-1]
    common = {"w_q": ws, "w_scale": lead + (out_ax,), "x_scale": lead}
    if mode in ("fast", "int8"):
        return dict(common, w_off=ws, centers=lead + (out_ax,),
                    scale=lead + (out_ax,))
    # exact: planes (n_slices, n_seg, rows_per_xbar, cols) per layer
    return dict(common, planes=lead + (None, None, None, out_ax),
                enc_centers=lead + (None, out_ax),
                slice_shifts=lead + (None,),
                slice_valid=lead + (None,))


def plan_specs(cfg: ArchConfig) -> dict | None:
    """Logical sharding axes mirroring ``compile_pim_params``'s plans."""
    if cfg.pim_mode == "off":
        return None
    pspecs = T.param_specs(cfg)
    blocks = []
    for i in range(len(cfg.block_pattern)):
        paths = _block_projections(cfg, i)
        if paths is None:
            blocks.append(None)
            continue
        blocks.append({
            g: {n: _site_specs(tuple(pspecs["blocks"][i][g][n]),
                               cfg.pim_mode)
                for n in names}
            for g, names in paths.items()})
    head = _site_specs(tuple(pspecs["embed"]["head"]), cfg.pim_mode)
    return {"embed": {"head": head}, "blocks": blocks}
