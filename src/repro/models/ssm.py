"""State-space / linear-attention blocks: Mamba (Jamba) and RWKV6 (Finch).

Both use O(1)-state recurrences lowered as *chunked* lax.scan: the outer scan
carries only chunk-boundary states (rematerialized inner steps), which keeps
train-time activation memory linear in n_chunks instead of seq_len — the
reason these architectures run the long_500k shape at all.

Decode is a single-step state update (the whole point of the family).

RWKV6 follows the Finch formulation: per-head matrix state
S_t = diag(w_t) S_{t-1} + k_t^T v_t with *data-dependent* decay w_t produced
by a low-rank MLP on the token-shifted input (the paper's ddlerp is
simplified to a single learned lerp + LoRA decay; noted in DESIGN.md).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import shard
from repro.models.layers import pim_matmul, plan_leaf

SCAN_CHUNK = 128


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _chunked_scan(step_fn, h0, xs, chunk: int, remat: bool):
    """scan(step_fn, h0, xs) over time axis 0, chunked with remat.

    Sequence-pad steps are masked to identity on the carry so the final
    state is exactly the state after the last *real* step.
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    n = -(-T // chunk)
    pad = n * chunk - T
    xs_p = jax.tree.map(
        lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)), xs)
    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs_p)
    valid = (jnp.arange(n * chunk) < T).reshape(n, chunk)

    def masked_step(h, xv):
        x_t, v = xv
        h2, y = step_fn(h, x_t)
        h2 = jax.tree.map(lambda a, b: jnp.where(v, a, b), h2, h)
        return h2, y

    def chunk_body(h, xc):
        return jax.lax.scan(masked_step, h, xc)

    if remat:
        chunk_body = jax.checkpoint(chunk_body)
    h, ys = jax.lax.scan(chunk_body, h0, (xs_c, valid))
    ys = jax.tree.map(
        lambda a: a.reshape((n * chunk,) + a.shape[2:])[:T], ys)
    return h, ys


# ===================================================================== mamba
def mamba_dims(cfg: ArchConfig):
    di = cfg.mamba_expand * cfg.d_model
    dtr = max(1, math.ceil(cfg.d_model / 16))
    return di, dtr, cfg.mamba_d_state, cfg.mamba_conv


def init_mamba(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d = cfg.d_model
    di, dtr, ds, conv = mamba_dims(cfg)
    dt = _dt(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dt) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (conv, di), dt) * conv ** -0.5,
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * ds), dt) * di ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dt) * dtr ** -0.5,
        "dt_bias": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds)) * 1.0),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), dt) * di ** -0.5,
    }
    s = {
        "in_proj": ("fsdp", "tp"), "conv_w": (None, "tp"), "conv_b": ("tp",),
        "x_proj": ("tp", None), "dt_proj": (None, "tp"), "dt_bias": ("tp",),
        "A_log": ("tp", None), "D": ("tp",),
        "out_proj": ("tp", "fsdp"),
    }
    return p, s


def _mamba_step(params, cfg, h, xt, bt, ct, dtt):
    """One recurrence step. h (B, di, ds); xt/dtt (B, di); bt/ct (B, ds)."""
    A = -jnp.exp(params["A_log"])                      # (di, ds)
    dA = jnp.exp(dtt[..., None] * A[None])             # (B, di, ds)
    h = dA * h + (dtt * xt)[..., None] * bt[:, None, :]
    h = shard(h, "batch", "tp", None)  # carry stays sharded across the scan
    y = jnp.einsum("bds,bs->bd", h, ct) \
        + params["D"][None, :] * xt
    return h, shard(y, "batch", "tp")


def _mamba_preprocess(params, cfg, x, conv_state=None, plans=None):
    """Shared projections. x (B, S, d) -> (xin, z, dt, B, C) all (B, S, ...).

    ``plans`` routes the weight-static in/x projections through
    ``cfg.pim_mode`` (the depthwise conv and low-rank dt path stay float —
    they are not crossbar-shaped matmuls)."""
    di, dtr, ds, conv = mamba_dims(cfg)
    xz = pim_matmul(x, params["in_proj"], plan_leaf(plans, "in_proj"), cfg)
    xin, z = jnp.split(xz, 2, axis=-1)
    # TP over d_inner: the selective-scan recurrence is elementwise in di,
    # so this layout keeps the whole recurrence device-local. (Seq cannot
    # stay sharded — it is the sequential scan axis.)
    xin = shard(xin, "batch", None, "tp")
    z = shard(z, "batch", None, "tp")
    # causal depthwise conv (kernel `conv`) as shifted adds
    if conv_state is None:
        hist = jnp.concatenate(
            [jnp.zeros_like(xin[:, :conv - 1]), xin], axis=1)
    else:
        hist = jnp.concatenate([conv_state.astype(xin.dtype), xin], axis=1)
    xc = sum(params["conv_w"][i][None, None, :]
             * jax.lax.dynamic_slice_in_dim(hist, i, xin.shape[1], axis=1)
             for i in range(conv))
    xc = jax.nn.silu(xc + params["conv_b"])
    new_conv_state = hist[:, -(conv - 1):] if conv > 1 else hist[:, :0]
    dbc = pim_matmul(xc, params["x_proj"], plan_leaf(plans, "x_proj"), cfg)
    dt_lr, bmat, cmat = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt_full = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_lr, params["dt_proj"])
        + params["dt_bias"]).astype(jnp.float32)
    return (xc.astype(jnp.float32), z, dt_full,
            bmat.astype(jnp.float32), cmat.astype(jnp.float32),
            new_conv_state)


def mamba_block(params: dict, cfg: ArchConfig, x: jnp.ndarray,
                plans=None) -> jnp.ndarray:
    """Full-sequence Mamba (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    di, dtr, ds, conv = mamba_dims(cfg)
    xc, z, dt_full, bmat, cmat, _ = _mamba_preprocess(params, cfg, x,
                                                      plans=plans)

    def step(h, xs_t):
        xt, bt, ct, dtt = xs_t
        return _mamba_step(params, cfg, h, xt, bt, ct, dtt)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(bmat, 1, 0),
          jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(dt_full, 1, 0))
    _, ys = _chunked_scan(step, h0, xs, SCAN_CHUNK, cfg.remat)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)         # (B, S, di)
    y = y * jax.nn.silu(z)
    return pim_matmul(y, params["out_proj"], plan_leaf(plans, "out_proj"),
                      cfg)


def init_mamba_state(cfg: ArchConfig, batch: int) -> dict:
    di, dtr, ds, conv = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, conv - 1, di), _dt(cfg)),
    }


def mamba_decode_step(params: dict, cfg: ArchConfig, state: dict,
                      x: jnp.ndarray, plans=None) -> tuple[dict, jnp.ndarray]:
    """x: (B, 1, d) -> (new_state, y (B, 1, d))."""
    xc, z, dt_full, bmat, cmat, new_conv = _mamba_preprocess(
        params, cfg, x, conv_state=state["conv"], plans=plans)
    h, y = _mamba_step(params, cfg, state["h"], xc[:, 0], bmat[:, 0],
                       cmat[:, 0], dt_full[:, 0])
    y = (y[:, None, :]).astype(x.dtype) * jax.nn.silu(z)
    out = pim_matmul(y, params["out_proj"], plan_leaf(plans, "out_proj"),
                     cfg)
    return {"h": h, "conv": new_conv}, out


# ===================================================================== rwkv6
def rwkv_dims(cfg: ArchConfig):
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd


def init_rwkv(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d = cfg.d_model
    H, hd = rwkv_dims(cfg)
    dt = _dt(cfg)
    ks = jax.random.split(key, 8)
    lora = 64
    p = {
        # token-shift lerp coefficients for r/k/v/g/w
        "mix": jax.random.uniform(ks[0], (5, d), dt, 0.0, 1.0),
        "wr": jax.random.normal(ks[1], (d, d), dt) * d ** -0.5,
        "wk": jax.random.normal(ks[2], (d, d), dt) * d ** -0.5,
        "wv": jax.random.normal(ks[3], (d, d), dt) * d ** -0.5,
        "wg": jax.random.normal(ks[4], (d, d), dt) * d ** -0.5,
        "wo": jax.random.normal(ks[5], (d, d), dt) * d ** -0.5,
        # data-dependent decay LoRA (Finch)
        "decay_base": jnp.full((d,), -1.0, jnp.float32),
        "decay_A": jax.random.normal(ks[6], (d, lora), dt) * d ** -0.5,
        "decay_B": jax.random.normal(ks[7], (lora, d), dt) * lora ** -0.5,
        "bonus": jnp.zeros((H, hd), jnp.float32),      # u
        "ln_x": jnp.ones((d,), dt),                    # post-wkv group norm
    }
    s = {
        "mix": (None, None),
        "wr": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
        "wg": ("fsdp", "tp"), "wo": ("tp", "fsdp"),
        "decay_base": (None,), "decay_A": (None, None), "decay_B": (None, None),
        "bonus": ("heads", None), "ln_x": (None,),
    }
    return p, s


def _rwkv_project(params, cfg, x, x_prev):
    """Token-shift + projections. x (B,S,d); x_prev (B,S,d) = shift(x)."""
    H, hd = rwkv_dims(cfg)
    B, S, d = x.shape
    mixed = [x + (x_prev - x) * params["mix"][i] for i in range(5)]
    r = jnp.einsum("bsd,de->bse", mixed[0], params["wr"])
    k = jnp.einsum("bsd,de->bse", mixed[1], params["wk"])
    v = jnp.einsum("bsd,de->bse", mixed[2], params["wv"])
    g = jnp.einsum("bsd,de->bse", mixed[3], params["wg"])
    dec = params["decay_base"] + jnp.einsum(
        "bsd,dl,le->bse", mixed[4], params["decay_A"], params["decay_B"])
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32)))     # (B, S, d) in (0,1)
    rh = r.reshape(B, S, H, hd).astype(jnp.float32)
    kh = k.reshape(B, S, H, hd).astype(jnp.float32)
    vh = shard(v.reshape(B, S, H, hd).astype(jnp.float32),
               "batch", None, None, "tp")
    wh = w.reshape(B, S, H, hd)
    return rh, kh, vh, wh, g


def _rwkv_step(params, h, rt, kt, vt, wt):
    """h (B,H,hd,hd); rt/kt/vt/wt (B,H,hd) -> (h', y (B,H,hd))."""
    u = params["bonus"][None]                          # (1,H,hd)
    kv = kt[..., :, None] * vt[..., None, :]           # (B,H,hd,hd)
    y = jnp.einsum("bhi,bhij->bhj", rt, h + u[..., None] * kv)
    h = wt[..., None] * h + kv
    # state S[i, j]: decay acts on i (key channels), output contracts i.
    # Sharding j (value channels) keeps the recurrence fully local.
    h = shard(h, "batch", None, None, "tp")
    return h, shard(y, "batch", None, "tp")


def rwkv_time_mix(params: dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence RWKV6 time-mix. x: (B, S, d)."""
    B, S, d = x.shape
    H, hd = rwkv_dims(cfg)
    x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    rh, kh, vh, wh, g = _rwkv_project(params, cfg, x, x_prev)

    def step(h, xs_t):
        rt, kt, vt, wt = xs_t
        return _rwkv_step(params, h, rt, kt, vt, wt)

    h0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rh, kh, vh, wh))
    _, ys = _chunked_scan(step, h0, xs, SCAN_CHUNK, cfg.remat)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d).astype(x.dtype)
    # group-norm-ish scale + silu(g) gate (Finch output path)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True)
                          + cfg.norm_eps)
    y = y * params["ln_x"] * jax.nn.silu(g)
    return jnp.einsum("bsd,de->bse", y, params["wo"])


def init_rwkv_state(cfg: ArchConfig, batch: int) -> dict:
    H, hd = rwkv_dims(cfg)
    return {
        "h": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), _dt(cfg)),
        "cm_prev": jnp.zeros((batch, cfg.d_model), _dt(cfg)),
    }


def rwkv_time_mix_decode(params: dict, cfg: ArchConfig, state: dict,
                         x: jnp.ndarray) -> tuple[dict, jnp.ndarray]:
    """x: (B, 1, d)."""
    B, _, d = x.shape
    x_prev = state["x_prev"][:, None, :]
    rh, kh, vh, wh, g = _rwkv_project(params, cfg, x, x_prev)
    h, y = _rwkv_step(params, state["h"], rh[:, 0], kh[:, 0], vh[:, 0],
                      wh[:, 0])
    y = y.reshape(B, 1, d).astype(x.dtype)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True)
                          + cfg.norm_eps)
    y = y * params["ln_x"] * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, params["wo"])
    new_state = dict(state, h=h, x_prev=x[:, 0])
    return new_state, out


# rwkv channel-mix (plays the FFN role; relu^2 + receptance gate)
def init_rwkv_channel_mix(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "mix": jax.random.uniform(ks[0], (2, d), dt, 0.0, 1.0),
        "wk": jax.random.normal(ks[1], (d, f), dt) * d ** -0.5,
        "wv": jax.random.normal(ks[2], (f, d), dt) * f ** -0.5,
        "wr": jax.random.normal(ks[0], (d, d), dt) * d ** -0.5,
    }
    s = {"mix": (None, None), "wk": ("fsdp", "tp"), "wv": ("tp", "fsdp"),
         "wr": ("fsdp", None)}
    return p, s


def rwkv_channel_mix(params: dict, cfg: ArchConfig, x: jnp.ndarray,
                     x_prev: jnp.ndarray | None = None) -> jnp.ndarray:
    if x_prev is None:
        x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * params["mix"][0]
    xr = x + (x_prev - x) * params["mix"][1]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["wk"])))
    k = shard(k, "batch", "seq", "tp")
    kv = jnp.einsum("bsf,fd->bsd", k, params["wv"])
    return jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["wr"])) * kv
