"""Model assembly: pattern-of-blocks transformer covering all 10 assigned
architectures (dense / MoE GQA transformers, Mamba+attn hybrid, RWKV6,
encoder-only, early-fusion VLM backbone).

Layers are stacked as `lax.scan` over *pattern repeats* (pattern length 1
for uniform archs, 8 for Jamba's 7:1 mamba:attn interleave), with
`jax.checkpoint` per repeat — HLO size stays flat in depth and activation
memory is one boundary tensor per repeat.

Public surface:
  init_params(cfg, key)            -> (params, specs)
  forward(params, cfg, batch)      -> logits            (train / no cache)
  init_decode_state(cfg, B, L)     -> state             (caches + position)
  prefill(params, cfg, tokens)     -> (logits, state)
  decode_step(params, cfg, state, tok) -> (logits, state)
  lm_loss(params, cfg, batch)      -> scalar

Paged KV (vLLM-style block tables — see ``repro.serve.paged``):
  PagedLayout(n_blocks, block_size)             pool geometry
  init_decode_state(..., paged=layout)          block-pool attn caches
  decode_step(..., block_tables=, paged=)       gather/write via tables
  prefill_chunk_paged(...)                      in-pool chunked prefill
  insert_request_paged(...)                     contiguous -> blocks scatter
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import shard
from repro.models import layers as L
from repro.models import ssm as S


# ------------------------------------------------------------------ init
def _init_block(key, cfg: ArchConfig, pattern_idx: int) -> tuple[dict, dict]:
    kind = cfg.block_pattern[pattern_idx]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, s = {}, {}
    p["norm1"], s["norm1"] = L.init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
    if kind == "attn":
        p["core"], s["core"] = L.init_attention(k1, cfg)
    elif kind == "mamba":
        p["core"], s["core"] = S.init_mamba(k1, cfg)
    elif kind == "rwkv":
        p["core"], s["core"] = S.init_rwkv(k1, cfg)
    else:
        raise ValueError(kind)
    p["norm2"], s["norm2"] = L.init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
    if kind == "rwkv":
        p["ffn"], s["ffn"] = S.init_rwkv_channel_mix(k2, cfg)
    elif cfg.moe_layer(pattern_idx):
        p["ffn"], s["ffn"] = L.init_moe(k2, cfg)
    else:
        p["ffn"], s["ffn"] = L.init_mlp(k2, cfg)
    return p, s


def param_specs(cfg: ArchConfig) -> dict:
    """Logical sharding axes mirroring the init_params pytree.

    Built from a reduced twin config (same structure, tiny shapes) so no
    full-size array is ever allocated — the dry-run calls this on 400B
    configs where a concrete init would not fit host memory.
    """
    tiny = cfg.reduced()
    specs = {}
    _, specs["embed"] = L.init_embedding(jax.random.key(0), tiny)
    bspecs = []
    for i in range(len(cfg.block_pattern)):
        _, s_one = _init_block(jax.random.key(0), tiny, i)
        s_stack = jax.tree.map(lambda ax: (None,) + tuple(ax), s_one,
                               is_leaf=lambda x: isinstance(x, tuple))
        bspecs.append(s_stack)
    specs["blocks"] = bspecs
    _, specs["final_norm"] = L.init_rmsnorm(tiny.d_model, jnp.dtype(tiny.dtype))
    return specs


def init_params(cfg: ArchConfig, key) -> tuple[dict, dict]:
    keys = jax.random.split(key, 2 + len(cfg.block_pattern))
    params = {}
    params["embed"], _ = L.init_embedding(keys[0], cfg)
    blocks = []
    for i in range(len(cfg.block_pattern)):
        rep_keys = jax.random.split(keys[1 + i], cfg.n_repeats)
        p_stack = jax.vmap(lambda k: _init_block(k, cfg, i)[0])(rep_keys)
        blocks.append(p_stack)
    params["blocks"] = blocks
    params["final_norm"], _ = L.init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype))
    return params, param_specs(cfg)


# ------------------------------------------------------------------ blocks
_subplan = L.plan_leaf  # ``plan[key]`` tolerating an absent plan tree


def _apply_block(kind: str, pattern_idx: int, bp: dict, cfg: ArchConfig,
                 x: jnp.ndarray, positions: jnp.ndarray,
                 plan=None) -> jnp.ndarray:
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        h = L.attention_block(bp["core"], cfg, h, positions,
                              plans=_subplan(plan, "core"))
    elif kind == "mamba":
        h = S.mamba_block(bp["core"], cfg, h, plans=_subplan(plan, "core"))
    elif kind == "rwkv":
        h = S.rwkv_time_mix(bp["core"], cfg, h)
    x = x + h
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    if kind == "rwkv":
        h = S.rwkv_channel_mix(bp["ffn"], cfg, h)
    elif cfg.moe_layer(pattern_idx):
        h = L.moe_block(bp["ffn"], cfg, h, plans=_subplan(plan, "ffn"))
    else:
        h = L.mlp_block(bp["ffn"], cfg, h, plans=_subplan(plan, "ffn"))
    x = x + h
    return shard(x, "batch", "seq", None)


# ------------------------------------------------------------------ forward
def embed_inputs(params: dict, cfg: ArchConfig, inputs: jnp.ndarray):
    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], cfg, inputs)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    return shard(x, "batch", "seq", None)


def _plan_blocks(cfg: ArchConfig, plans) -> tuple:
    """Per-pattern-position plan trees (Nones when no plans ride along)."""
    if plans is None:
        return tuple([None] * len(cfg.block_pattern))
    return tuple(plans["blocks"])


def forward_hidden(params: dict, cfg: ArchConfig, inputs: jnp.ndarray,
                   plans=None) -> jnp.ndarray:
    """Full-sequence forward to final hidden states (B, S, D).

    ``plans`` is the compiled PIM-plan pytree from
    ``repro.models.pim.prepare_pim_params``; its stacked block plans ride
    the ``lax.scan`` next to the stacked params.
    """
    x = embed_inputs(params, cfg, inputs)
    B, Seq = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Seq, dtype=jnp.int32), (B, Seq))

    def repeat_body(carry, xs):
        h = carry
        rep_params, rep_plans = xs
        for i, kind in enumerate(cfg.block_pattern):
            if cfg.remat and len(cfg.block_pattern) > 1:
                # nested remat: backward re-gathers one block's weights at a
                # time instead of the whole pattern body's (Jamba: 8 layers)
                h = jax.checkpoint(
                    lambda bp, pl, hh, _i=i, _k=kind: _apply_block(
                        _k, _i, bp, cfg, hh, positions, plan=pl))(
                            rep_params[i], rep_plans[i], h)
            else:
                h = _apply_block(kind, i, rep_params[i], cfg, h, positions,
                                 plan=rep_plans[i])
        return h, None

    body = jax.checkpoint(repeat_body) if cfg.remat else repeat_body
    with L.suspend_pim_stats():  # tracer hygiene — see _run_prefill_body
        x, _ = jax.lax.scan(
            body, x, (tuple(params["blocks"]), _plan_blocks(cfg, plans)))
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params: dict, cfg: ArchConfig, inputs: jnp.ndarray,
            plans=None) -> jnp.ndarray:
    """Full-sequence forward to logits. inputs: tokens (B,S) or embeds (B,S,D)."""
    return L.lm_head(params["embed"], cfg,
                     forward_hidden(params, cfg, inputs, plans),
                     plan=_subplan(_subplan(plans, "embed"), "head"))


# ------------------------------------------------------------------ losses
LOSS_CHUNK = 512  # seq positions per logits chunk (vocab up to 202k)


def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """Causal LM loss (tokens mode) or full-position unit-prediction loss
    (embeddings mode — hubert-style masked-unit proxy).

    Cross-entropy is computed in sequence chunks under remat so the
    (B, S, vocab) logits tensor never materializes — at vocab 202k /
    1M tokens the full fp32 logits alone would be ~0.8 TB.
    """
    x = forward_hidden(params, cfg, batch["inputs"])  # (B, S, D)
    labels = batch["labels"]
    mask = batch.get("mask")
    B, S, D = x.shape
    if cfg.input_mode == "tokens" and cfg.causal:
        # position t predicts labels[t+1]; last position masked out
        labels = jnp.concatenate(
            [labels[:, 1:], jnp.zeros((B, 1), labels.dtype)], axis=1)
        last = (jnp.arange(S) < S - 1).astype(jnp.float32)[None, :]
        mask = last * (jnp.ones((B, S), jnp.float32) if mask is None else mask)

    C = min(LOSS_CHUNK, S)
    nc = -(-S // C)
    pad = nc * C - S
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = jnp.broadcast_to(mask, (B, S))
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))
    xs = (shard(jnp.moveaxis(xp.reshape(B, nc, C, D), 1, 0),
                None, "batch", None, None),
          jnp.moveaxis(lp.reshape(B, nc, C), 1, 0),
          jnp.moveaxis(mp.reshape(B, nc, C), 1, 0))

    def body(carry, chunk):
        tot, cnt = carry
        xc, lc, mc = chunk
        xc = shard(xc, "batch", None, None)
        logits = jnp.einsum("bsd,dv->bsv", xc,
                            params["embed"]["head"]).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    z = jnp.zeros((), jnp.float32)
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body), (z, z), xs)
    return tot / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------------------ decode
@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Geometry of a paged KV pool (vLLM-style block tables).

    Attention caches become one pool of ``n_blocks`` fixed-size blocks
    shared by every slot, plus one *scratch* row at index ``n_blocks``
    (the ``sentinel``). A slot's block table maps block index ->
    pool row; table entries equal to the sentinel land writes in scratch
    and gather garbage that the attention ``kv_len`` mask then zeroes
    exactly, so idle or mid-prefill slots stay no-ops without any
    conditional in the jitted step. Recurrent (mamba/rwkv) carries are
    per-slot, not paged — they have no sequence axis to page.
    """
    n_blocks: int
    block_size: int

    @property
    def sentinel(self) -> int:
        return self.n_blocks

    @property
    def pool_rows(self) -> int:
        return self.n_blocks + 1

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int, *,
                      per_slot_pos: bool = False,
                      paged: PagedLayout | None = None) -> dict:
    """Stacked per-repeat caches for every pattern position.

    With ``per_slot_pos`` the state carries one position per batch slot
    (shape ``(batch,)``) instead of a single scalar, so each slot can sit
    at a different sequence offset — the substrate for continuous
    batching (see ``repro.serve.scheduler``).

    With ``paged`` the attention caches are block pools
    ``(n_repeats, n_blocks + 1, block_size, kv_heads, head_dim)`` instead
    of per-slot ``(batch, max_len)`` regions; ``decode_step`` then needs
    per-slot ``block_tables`` to address them. Recurrent carries keep
    their per-slot ``batch`` axis either way.
    """
    hd = cfg.resolved_head_dim
    kv_dt = jnp.dtype(cfg.kv_cache_dtype)
    caches = []
    for kind in cfg.block_pattern:
        if kind == "attn":
            if paged is not None:
                shape = (cfg.n_repeats, paged.pool_rows, paged.block_size,
                         cfg.n_kv_heads, hd)
            else:
                shape = (cfg.n_repeats, batch, max_len, cfg.n_kv_heads, hd)
            c = {"k": jnp.zeros(shape, kv_dt), "v": jnp.zeros(shape, kv_dt)}
            if kv_dt == jnp.int8:
                c["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
                c["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        elif kind == "mamba":
            one = S.init_mamba_state(cfg, batch)
            c = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.n_repeats,) + a.shape).copy(), one)
        else:  # rwkv
            one = S.init_rwkv_state(cfg, batch)
            c = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.n_repeats,) + a.shape).copy(), one)
        caches.append(c)
    pos = jnp.zeros((batch,) if per_slot_pos else (), jnp.int32)
    return {"caches": caches, "pos": pos}


def cache_specs(cfg: ArchConfig, *, paged: bool = False) -> dict:
    """Logical shardings for the decode state (KV cache seq-sharded).

    Paged pools shard their *block* axis on ``cache_batch`` (blocks play
    the role per-slot regions play contiguously: under
    ``MULTIPOD_SERVE_RULES`` the pool spreads over the decode slice's
    ``("pod", "data")`` product while weights stay stationary)."""
    caches = []
    for kind in cfg.block_pattern:
        if kind == "attn":
            if paged:
                c = {"k": (None, "cache_batch", None, "kv_heads", None),
                     "v": (None, "cache_batch", None, "kv_heads", None)}
            else:
                c = {"k": (None, "cache_batch", "seq", "kv_heads", None),
                     "v": (None, "cache_batch", "seq", "kv_heads", None)}
            if jnp.dtype(cfg.kv_cache_dtype) == jnp.int8:
                c["k_scale"] = c["k"][:-1]
                c["v_scale"] = c["v"][:-1]
        elif kind == "mamba":
            c = {"h": (None, "cache_batch", "tp", None),
                 "conv": (None, "cache_batch", None, "tp")}
        else:
            c = {"h": (None, "cache_batch", "heads", None, None),
                 "x_prev": (None, "cache_batch", None),
                 "cm_prev": (None, "cache_batch", None)}
        caches.append(c)
    return {"caches": caches, "pos": ()}


def _write_token(buf: jnp.ndarray, new: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Write a one-token slice ``new`` (B, 1, ...) into a (B, L, ...) cache.

    Scalar ``pos`` keeps the lockstep dynamic-update path; a ``(B,)`` pos
    scatters each slot's row at its own offset (``mode="drop"``:
    out-of-range per-slot positions write nothing, so retired/idle slots
    are no-ops).
    """
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), pos, axis=1)
    B = buf.shape[0]
    return buf.at[jnp.arange(B), pos].set(new[:, 0].astype(buf.dtype),
                                          mode="drop")


def _paged_write_token(pool: jnp.ndarray, new: jnp.ndarray,
                       pos: jnp.ndarray, tables: jnp.ndarray,
                       paged: PagedLayout) -> jnp.ndarray:
    """Write each slot's one-token slice ``new`` (B, 1, ...) into its
    current block of a ``(pool_rows, block_size, ...)`` pool leaf.

    Slots whose table entry is the sentinel (idle, retired, or still
    mid-prefill — the scheduler hands ``decode_step`` a sentinel row for
    them) write into the scratch block, which no live gather ever
    unmasks."""
    B = new.shape[0]
    bi = jnp.clip(pos // paged.block_size, 0, tables.shape[1] - 1)
    rows = tables[jnp.arange(B), bi]
    return pool.at[rows, pos % paged.block_size].set(
        new[:, 0].astype(pool.dtype))


def _paged_gather(pool: jnp.ndarray, tables: jnp.ndarray,
                  paged: PagedLayout) -> jnp.ndarray:
    """(pool_rows, block_size, ...) pool + (B, max_blocks) tables -> a
    (B, max_blocks * block_size, ...) contiguous-cache view.

    Sentinel entries gather the scratch block; those positions sit at or
    beyond ``kv_len``, so the attention mask turns them into exact-zero
    contributions and the view reduces bit-identically to a contiguous
    ``(B, max_len, ...)`` cache of the same total length."""
    B, max_blocks = tables.shape
    view = pool[jnp.clip(tables, 0, paged.sentinel)]
    return view.reshape((B, max_blocks * paged.block_size) + pool.shape[2:])


def _quantize_kv(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(..., hd) -> int8 codes + per-(token, head) fp32 scale (RAELLA-style
    low-precision storage with a digital correction factor)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _attn_decode(bp: dict, cfg: ArchConfig, cache: dict, x: jnp.ndarray,
                 pos: jnp.ndarray, plans=None, tables=None,
                 paged: PagedLayout | None = None) -> tuple[dict, jnp.ndarray]:
    """Single-token attention against the (sequence-sharded) KV cache.

    ``pos`` is a scalar (lockstep: the whole batch shares one position) or
    a ``(B,)`` vector (continuous batching: one position per slot). With
    ``tables``/``paged`` the cache leaves are block pools: the new token
    scatters into each slot's current block and attention reads a
    block-table gather of the slot's history.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        positions = jnp.broadcast_to(pos[None, None], (B, 1))
    else:
        positions = pos[:, None]
    q, k_new, v_new = L.qkv_project(bp["core"], cfg, x, positions, plans)
    # align the query/new-KV batch with the cache's batch sharding so the
    # whole attention stays device-local (otherwise the dequantized cache
    # moves across the mesh every step)
    q = shard(q, "cache_batch", None, None, None)
    k_new = shard(k_new, "cache_batch", None, None, None)
    v_new = shard(v_new, "cache_batch", None, None, None)
    int8_cache = jnp.dtype(cfg.kv_cache_dtype) == jnp.int8
    if tables is not None:
        write = lambda buf, new: shard(  # noqa: E731
            _paged_write_token(buf, new, pos, tables, paged),
            "cache_batch", None, "kv_heads", None)
        gather = lambda buf: _paged_gather(buf, tables, paged)  # noqa: E731
        if int8_cache:
            write_s = lambda buf, new: _paged_write_token(  # noqa: E731
                buf, new, pos, tables, paged)
    else:
        write = write_s = lambda buf, new: _write_token(  # noqa: E731
            buf, new, pos)
        gather = lambda buf: buf  # noqa: E731
    if int8_cache:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        new_cache = {
            "k": write(cache["k"], kq),
            "v": write(cache["v"], vq),
            "k_scale": write_s(cache["k_scale"], ks),
            "v_scale": write_s(cache["v_scale"], vs),
        }
        k_cache = _dequantize_kv(gather(new_cache["k"]),
                                 gather(new_cache["k_scale"]), x.dtype)
        v_cache = _dequantize_kv(gather(new_cache["v"]),
                                 gather(new_cache["v_scale"]), x.dtype)
    else:
        new_cache = {"k": write(cache["k"], k_new),
                     "v": write(cache["v"], v_new)}
        k_cache = gather(new_cache["k"])
        v_cache = gather(new_cache["v"])
    out = L.chunked_attention(q, k_cache, v_cache, q_positions=positions,
                              kv_len=pos + 1, causal=True)
    y = L.pim_matmul(out.reshape(B, 1, -1), bp["core"]["wo"],
                     L.plan_leaf(plans, "wo"), cfg)
    return new_cache, y


def _decode_block(kind: str, pattern_idx: int, bp: dict, cfg: ArchConfig,
                  cache: dict, x: jnp.ndarray, pos: jnp.ndarray, plan=None,
                  tables=None, paged: PagedLayout | None = None):
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        cache, h = _attn_decode(bp, cfg, cache, h, pos,
                                plans=_subplan(plan, "core"),
                                tables=tables, paged=paged)
    elif kind == "mamba":
        cache, h = S.mamba_decode_step(bp["core"], cfg, cache, h,
                                       plans=_subplan(plan, "core"))
    else:
        cache, h = S.rwkv_time_mix_decode(bp["core"], cfg, cache, h)
    x = x + h
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    if kind == "rwkv":
        h = S.rwkv_channel_mix(bp["ffn"], cfg, h,
                               x_prev=cache["cm_prev"][:, None, :])
        cache = dict(cache, cm_prev=L.rmsnorm(bp["norm2"], x, cfg.norm_eps)[:, 0])
    elif cfg.moe_layer(pattern_idx):
        h = L.moe_block(bp["ffn"], cfg, h, plans=_subplan(plan, "ffn"))
    else:
        h = L.mlp_block(bp["ffn"], cfg, h, plans=_subplan(plan, "ffn"))
    return cache, x + h


def decode_step(params: dict, cfg: ArchConfig, state: dict,
                tokens: jnp.ndarray, plans=None, *, block_tables=None,
                paged: PagedLayout | None = None) -> tuple[jnp.ndarray, dict]:
    """One decode step. tokens: (B, 1) ids or (B, 1, D) embeds.

    ``state["pos"]`` may be a scalar (lockstep) or ``(B,)`` (per-slot,
    continuous batching); every slot's position advances by one.

    With a paged state, pass ``block_tables`` ((B, max_blocks) int32 pool
    rows, sentinel = ``paged.sentinel`` for unmapped entries) and the
    matching ``paged`` layout: each slot writes its token into its
    current block and attends over a block-table gather — bit-identical
    to the contiguous cache when ``max_blocks * block_size == max_len``
    (masked positions contribute exact zeros either way). Slots the
    scheduler does not want touched (mid-prefill) should be handed an
    all-sentinel table row, which turns their write into a scratch-block
    no-op.
    """
    if (block_tables is None) != (paged is None):
        raise ValueError("block_tables and paged must be passed together")
    x = embed_inputs(params, cfg, tokens)
    pos = state["pos"]
    if block_tables is not None and pos.ndim == 0:
        raise ValueError("paged decode needs per-slot positions "
                         "(init_decode_state(..., per_slot_pos=True))")
    # sow-style work-stats collection (see layers.collect_pim_stats):
    # stats tracers born inside the scanned block body belong to the
    # scan sub-trace, so the body opens its OWN sink and re-emits the
    # summed totals as scan outputs; the per-repeat stacks are reduced
    # below and recorded into the caller's sink as outer-trace values.
    collect = L.pim_stats_active()

    def repeat_body(carry, xs):
        h = carry
        rep_params, rep_caches, rep_plans = xs
        new_caches = []
        ctx = L.collect_pim_stats() if collect else contextlib.nullcontext([])
        with ctx as inner:
            for i, kind in enumerate(cfg.block_pattern):
                c, h = _decode_block(kind, i, rep_params[i], cfg,
                                     rep_caches[i], h, pos,
                                     plan=rep_plans[i],
                                     tables=block_tables, paged=paged)
                new_caches.append(c)
        if collect:
            totals = {k: jnp.asarray(v)
                      for k, v in L.pim_stats_totals(inner).items()}
            return h, (tuple(new_caches), totals)
        return h, tuple(new_caches)

    x, ys = jax.lax.scan(
        repeat_body, x, (tuple(params["blocks"]), tuple(state["caches"]),
                         _plan_blocks(cfg, plans)))
    if collect:
        new_caches, rep_totals = ys
        L.pim_stats_record({k: v.sum(axis=0) for k, v in rep_totals.items()})
    else:
        new_caches = ys
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x,
                       plan=_subplan(_subplan(plans, "embed"), "head"))
    new_state = {"caches": list(new_caches), "pos": pos + 1}
    return logits, new_state


# ------------------------------------------------------------------ prefill
def _prefill_repeat_body(cfg: ArchConfig, B: int, C: int,
                         positions: jnp.ndarray, pos0: jnp.ndarray,
                         kv_len: jnp.ndarray, raw_attn: bool):
    """Shared per-repeat body for whole-prompt and chunked prefill.

    Consumes ``(rep_params, rep_caches)`` and writes the processed chunk
    into the caches at ``pos0``. ``raw_attn`` selects where attention
    reads K/V from: this call's raw projections (whole-prompt prefill —
    also the encoder path, honoring ``cfg.causal``) or the cache buffer
    (chunked continuation: earlier chunks are only available there).
    The recurrent mamba/rwkv branches continue from the cached carries
    either way — a zero-initialized state makes them identical to a
    fresh forward.
    """
    int8_cache = jnp.dtype(cfg.kv_cache_dtype) == jnp.int8

    def repeat_body(carry, xs):
        h = carry
        rep_params, rep_caches, rep_plans = xs
        new_caches = []
        for i, kind in enumerate(cfg.block_pattern):
            bp, cache, plan = rep_params[i], rep_caches[i], rep_plans[i]
            core_plan = _subplan(plan, "core")
            hn = L.rmsnorm(bp["norm1"], h, cfg.norm_eps)
            if kind == "attn":
                q, k, v = L.qkv_project(bp["core"], cfg, hn, positions,
                                        core_plan)
                if int8_cache:
                    kq, ks = _quantize_kv(k)
                    vq, vs = _quantize_kv(v)
                    cache = {
                        "k": jax.lax.dynamic_update_slice_in_dim(
                            cache["k"], kq, pos0, axis=1),
                        "v": jax.lax.dynamic_update_slice_in_dim(
                            cache["v"], vq, pos0, axis=1),
                        "k_scale": jax.lax.dynamic_update_slice_in_dim(
                            cache["k_scale"], ks, pos0, axis=1),
                        "v_scale": jax.lax.dynamic_update_slice_in_dim(
                            cache["v_scale"], vs, pos0, axis=1),
                    }
                else:
                    cache = {
                        "k": jax.lax.dynamic_update_slice_in_dim(
                            cache["k"], k.astype(cache["k"].dtype), pos0,
                            axis=1),
                        "v": jax.lax.dynamic_update_slice_in_dim(
                            cache["v"], v.astype(cache["v"].dtype), pos0,
                            axis=1),
                    }
                cache["k"] = shard(cache["k"], "cache_batch", "seq",
                                   "kv_heads", None)
                cache["v"] = shard(cache["v"], "cache_batch", "seq",
                                   "kv_heads", None)
                if raw_attn:
                    q = shard(q, "batch", "seq", None, None)
                    o = L.chunked_attention(q, k, v, q_positions=positions,
                                            kv_len=kv_len,
                                            causal=cfg.causal)
                else:
                    q = shard(q, "cache_batch", None, None, None)
                    if int8_cache:
                        k_all = _dequantize_kv(cache["k"], cache["k_scale"],
                                               hn.dtype)
                        v_all = _dequantize_kv(cache["v"], cache["v_scale"],
                                               hn.dtype)
                    else:
                        k_all = cache["k"].astype(hn.dtype)
                        v_all = cache["v"].astype(hn.dtype)
                    o = L.chunked_attention(q, k_all, v_all,
                                            q_positions=positions,
                                            kv_len=kv_len, causal=True)
                core_out = L.pim_matmul(o.reshape(B, C, -1),
                                        bp["core"]["wo"],
                                        L.plan_leaf(core_plan, "wo"), cfg)
            elif kind == "mamba":
                xc, z, dtf, bm, cm, new_conv = S._mamba_preprocess(
                    bp["core"], cfg, hn, conv_state=cache["conv"],
                    plans=core_plan)

                def step(hh, xs_t):
                    xt, bt, ct, dtt = xs_t
                    return S._mamba_step(bp["core"], cfg, hh, xt, bt, ct, dtt)

                xs_seq = tuple(jnp.moveaxis(a, 1, 0)
                               for a in (xc, bm, cm, dtf))
                h_fin, ys = S._chunked_scan(step, cache["h"], xs_seq,
                                            S.SCAN_CHUNK, cfg.remat)
                y = jnp.moveaxis(ys, 0, 1).astype(hn.dtype) * jax.nn.silu(z)
                core_out = L.pim_matmul(y, bp["core"]["out_proj"],
                                        L.plan_leaf(core_plan, "out_proj"),
                                        cfg)
                cache = {"h": h_fin, "conv": new_conv}
            else:  # rwkv
                x_prev = jnp.concatenate(
                    [cache["x_prev"][:, None].astype(hn.dtype), hn[:, :-1]],
                    axis=1)
                rh, kh, vh, wh, g = S._rwkv_project(bp["core"], cfg, hn,
                                                    x_prev)

                def step(hh, xs_t):
                    rt, kt, vt, wt = xs_t
                    return S._rwkv_step(bp["core"], hh, rt, kt, vt, wt)

                xs_seq = tuple(jnp.moveaxis(a, 1, 0)
                               for a in (rh, kh, vh, wh))
                h_fin, ys = S._chunked_scan(step, cache["h"], xs_seq,
                                            S.SCAN_CHUNK, cfg.remat)
                y = jnp.moveaxis(ys, 0, 1).reshape(hn.shape).astype(hn.dtype)
                y = y * jax.lax.rsqrt(
                    jnp.mean(jnp.square(y), -1, keepdims=True) + cfg.norm_eps)
                y = y * bp["core"]["ln_x"] * jax.nn.silu(g)
                core_out = jnp.einsum("bsd,de->bse", y, bp["core"]["wo"])
                cm_prev_in = cache["cm_prev"]
                cache = {"h": h_fin, "x_prev": hn[:, -1]}
            h = h + core_out
            hn2 = L.rmsnorm(bp["norm2"], h, cfg.norm_eps)
            if kind == "rwkv":
                cm_hist = jnp.concatenate(
                    [cm_prev_in[:, None].astype(hn2.dtype), hn2[:, :-1]],
                    axis=1)
                ffn_out = S.rwkv_channel_mix(bp["ffn"], cfg, hn2,
                                             x_prev=cm_hist)
                cache["cm_prev"] = hn2[:, -1]
            elif cfg.moe_layer(i):
                ffn_out = L.moe_block(bp["ffn"], cfg, hn2,
                                      plans=_subplan(plan, "ffn"))
            else:
                ffn_out = L.mlp_block(bp["ffn"], cfg, hn2,
                                      plans=_subplan(plan, "ffn"))
            h = shard(h + ffn_out, "batch", "seq", None)
            new_caches.append(cache)
        return h, tuple(new_caches)

    return repeat_body


def _run_prefill_body(params: dict, cfg: ArchConfig, x: jnp.ndarray,
                      caches, body, plans=None) -> tuple[jnp.ndarray, list]:
    body = jax.checkpoint(body) if cfg.remat else body
    # work-stats collection is decode-focused: suspend sinks while the
    # scan traces its body so block-internal stats tracers cannot leak
    # (the converts/token metric bills decode steps; lm_head below still
    # records — it sits outside the scan)
    with L.suspend_pim_stats():
        x, new_caches = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(caches),
                      _plan_blocks(cfg, plans)))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params["embed"], cfg, x[:, -1:],
                       plan=_subplan(_subplan(plans, "embed"), "head"))
    return logits, list(new_caches)


def prefill(params: dict, cfg: ArchConfig, inputs: jnp.ndarray,
            max_len: int | None = None,
            plans=None) -> tuple[jnp.ndarray, dict]:
    """Process a prompt, returning last-position logits + a filled decode
    state. Cache buffers sized to max_len (default: prompt length).
    Attention runs over this call's raw K/V (``causal=cfg.causal``, so
    encoder-only archs work too); K/V are then stored into the cache."""
    x = embed_inputs(params, cfg, inputs)
    B, Seq = x.shape[0], x.shape[1]
    max_len = max_len or Seq
    state = init_decode_state(cfg, B, max_len)
    positions = jnp.broadcast_to(jnp.arange(Seq, dtype=jnp.int32), (B, Seq))
    body = _prefill_repeat_body(cfg, B, Seq, positions,
                                pos0=jnp.zeros((), jnp.int32),
                                kv_len=Seq, raw_attn=True)
    logits, caches = _run_prefill_body(params, cfg, x, state["caches"], body,
                                       plans=plans)
    return logits, {"caches": caches, "pos": jnp.asarray(Seq, jnp.int32)}


def prefill_chunk(params: dict, cfg: ArchConfig, state: dict,
                  tokens: jnp.ndarray, plans=None) -> tuple[jnp.ndarray, dict]:
    """Process the next prompt chunk of an in-flight (chunked) prefill.

    ``state`` is a scalar-pos decode state whose caches hold positions
    ``[0, state["pos"])``; ``tokens`` (B, C) ids — or (B, C, D) embeds —
    continue the prompt at that offset. Returns last-position logits and
    the advanced state, exactly like ``prefill``.

    ``init_decode_state`` followed by ``prefill_chunk`` over the whole
    prompt reproduces ``prefill`` bit-for-bit for float KV caches (the
    recurrent mamba/rwkv states continue their scans from the cached
    carry; attention reads earlier chunks back out of the cache, which is
    value-preserving when the cache dtype holds K/V exactly). With an
    int8 KV cache each chunk boundary inserts one quantize/dequantize
    round-trip that whole-prompt ``prefill`` does not have.
    """
    x = embed_inputs(params, cfg, tokens)
    B, C = x.shape[0], x.shape[1]
    pos0 = jnp.asarray(state["pos"], jnp.int32)
    positions = jnp.broadcast_to(pos0 + jnp.arange(C, dtype=jnp.int32),
                                 (B, C))
    body = _prefill_repeat_body(cfg, B, C, positions, pos0=pos0,
                                kv_len=pos0 + C, raw_attn=False)
    logits, caches = _run_prefill_body(params, cfg, x, state["caches"], body,
                                       plans=plans)
    return logits, {"caches": caches, "pos": pos0 + C}


def insert_request(state: dict, one: dict, slot: jnp.ndarray) -> dict:
    """Splice a batch-1 decode state into slot ``slot`` of a batched state.

    ``state`` must carry per-slot positions (``init_decode_state(...,
    per_slot_pos=True)``); ``one`` is a scalar-pos state produced by
    ``prefill``/``prefill_chunk`` at batch 1. Every cache leaf is written
    along the batch axis (axis 1 — leaves are stacked per repeat), so the
    slot's previous contents are fully replaced.
    """
    caches = jax.tree.map(
        lambda c, o: jax.lax.dynamic_update_slice_in_dim(
            c, o.astype(c.dtype), slot, axis=1),
        state["caches"], one["caches"])
    pos = state["pos"].at[slot].set(jnp.asarray(one["pos"], jnp.int32))
    return {"caches": caches, "pos": pos}


# ----------------------------------------------------------------- paged
def prefill_chunk_paged(params: dict, cfg: ArchConfig, state: dict,
                        tokens: jnp.ndarray, *, slot, table_row, pos0,
                        paged: PagedLayout,
                        plans=None) -> tuple[jnp.ndarray, dict]:
    """Advance one slot's in-flight prefill *inside* the shared block pool
    (copy-free admission: the prompt streams straight into the slot's
    claimed blocks, never through a contiguous staging region).

    ``state`` is the batched paged decode state; ``tokens`` (1, C) is the
    next prompt chunk for ``slot``, whose earlier context — including
    refcount-shared prefix blocks — is read back through ``table_row``
    ((max_blocks,) int32 pool rows). ``pos0`` is the chunk's absolute
    start offset, passed explicitly because the batched ``pos[slot]``
    keeps advancing with every interleaved decode step while this slot is
    still prefilling (those decode writes land in the sentinel scratch
    block); the final value ``pos0 + C`` is written back into
    ``pos[slot]`` so a completed prefill leaves the slot decode-ready.

    Bit-identity with the contiguous ``prefill_chunk`` path follows from
    the gather argument in ``_paged_gather``; the shared-prefix case
    additionally relies on chunked prefill being boundary-independent for
    float KV caches (the chunk after a shared prefix starts at a block
    boundary, not necessarily a ``prefill_chunk`` multiple).

    Attention-only patterns: recurrent (mamba/rwkv) carries cannot be
    rebuilt from paged context — recurrent archs stage their prefill at
    B=1 and hand the result over via ``insert_request_paged``.
    """
    bad = [k for k in cfg.block_pattern if k != "attn"]
    if bad:
        raise ValueError(
            f"prefill_chunk_paged supports attention-only patterns; "
            f"{cfg.name} has {bad} blocks — stage the prefill at B=1 and "
            f"use insert_request_paged")
    int8_cache = jnp.dtype(cfg.kv_cache_dtype) == jnp.int8
    x = embed_inputs(params, cfg, tokens)
    B, C = x.shape[0], x.shape[1]
    pos0 = jnp.asarray(pos0, jnp.int32)
    abs_pos = pos0 + jnp.arange(C, dtype=jnp.int32)          # (C,)
    positions = jnp.broadcast_to(abs_pos[None], (B, C))
    max_blocks = table_row.shape[0]
    rows_c = table_row[jnp.clip(abs_pos // paged.block_size, 0,
                                max_blocks - 1)]             # (C,)
    offs_c = abs_pos % paged.block_size
    tables1 = table_row[None]                                # (1, max_blocks)

    def repeat_body(carry, xs):
        h = carry
        rep_params, rep_caches, rep_plans = xs
        new_caches = []
        for i, _ in enumerate(cfg.block_pattern):
            bp, cache, plan = rep_params[i], rep_caches[i], rep_plans[i]
            core_plan = _subplan(plan, "core")
            hn = L.rmsnorm(bp["norm1"], h, cfg.norm_eps)
            q, k, v = L.qkv_project(bp["core"], cfg, hn, positions, core_plan)
            if int8_cache:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                cache = {
                    "k": cache["k"].at[rows_c, offs_c].set(kq[0]),
                    "v": cache["v"].at[rows_c, offs_c].set(vq[0]),
                    "k_scale": cache["k_scale"].at[rows_c, offs_c].set(ks[0]),
                    "v_scale": cache["v_scale"].at[rows_c, offs_c].set(vs[0]),
                }
                k_all = _dequantize_kv(
                    _paged_gather(cache["k"], tables1, paged),
                    _paged_gather(cache["k_scale"], tables1, paged), hn.dtype)
                v_all = _dequantize_kv(
                    _paged_gather(cache["v"], tables1, paged),
                    _paged_gather(cache["v_scale"], tables1, paged), hn.dtype)
            else:
                cache = {
                    "k": cache["k"].at[rows_c, offs_c].set(
                        k[0].astype(cache["k"].dtype)),
                    "v": cache["v"].at[rows_c, offs_c].set(
                        v[0].astype(cache["v"].dtype)),
                }
                k_all = _paged_gather(cache["k"], tables1, paged).astype(
                    hn.dtype)
                v_all = _paged_gather(cache["v"], tables1, paged).astype(
                    hn.dtype)
            cache = {kk: shard(vv, "cache_batch", None, "kv_heads", None)
                     if vv.ndim == 4 else vv for kk, vv in cache.items()}
            q = shard(q, "cache_batch", None, None, None)
            o = L.chunked_attention(q, k_all, v_all, q_positions=positions,
                                    kv_len=pos0 + C, causal=True)
            h = h + L.pim_matmul(o.reshape(B, C, -1), bp["core"]["wo"],
                                 L.plan_leaf(core_plan, "wo"), cfg)
            hn2 = L.rmsnorm(bp["norm2"], h, cfg.norm_eps)
            if cfg.moe_layer(i):
                ffn_out = L.moe_block(bp["ffn"], cfg, hn2,
                                      plans=_subplan(plan, "ffn"))
            else:
                ffn_out = L.mlp_block(bp["ffn"], cfg, hn2,
                                      plans=_subplan(plan, "ffn"))
            h = shard(h + ffn_out, "batch", "seq", None)
            new_caches.append(cache)
        return h, tuple(new_caches)

    logits, caches = _run_prefill_body(params, cfg, x, state["caches"],
                                       repeat_body, plans=plans)
    pos = state["pos"].at[jnp.asarray(slot, jnp.int32)].set(pos0 + C)
    return logits, {"caches": caches, "pos": pos}


def insert_request_paged(state: dict, one: dict, slot, table_row,
                         paged: PagedLayout) -> dict:
    """Scatter a contiguous B=1 prefilled state into a slot's pool blocks.

    The staged-admission / cross-slice handoff path: recurrent archs
    prefill at B=1 off-pool (their carries cannot be rebuilt from paged
    context), and disaggregated serving prefills on a separate mesh slice
    before handing the filled blocks to the decode slice. Attention
    leaves scatter every position ``p`` of the contiguous cache into pool
    row ``table_row[p // block_size]`` at offset ``p % block_size``
    (sentinel rows absorb the unused tail in scratch); recurrent carries
    and ``pos[slot]`` splice exactly like ``insert_request``.
    """
    slot = jnp.asarray(slot, jnp.int32)
    bs = paged.block_size
    new_caches = []
    for cache, cone in zip(state["caches"], one["caches"]):
        if "k" in cache:  # attn: pool (R, rows, bs, K[, hd])
            max_len = cone["k"].shape[2]
            p = jnp.arange(max_len, dtype=jnp.int32)
            rows = jnp.clip(
                table_row[jnp.clip(p // bs, 0, table_row.shape[0] - 1)],
                0, paged.sentinel)
            c = {kk: cache[kk].at[:, rows, p % bs].set(
                     cone[kk][:, 0].astype(cache[kk].dtype))
                 for kk in cache}
        else:  # recurrent carries: per-slot batch axis
            c = jax.tree.map(
                lambda cc, oo: jax.lax.dynamic_update_slice_in_dim(
                    cc, oo.astype(cc.dtype), slot, axis=1), cache, cone)
        new_caches.append(c)
    pos = state["pos"].at[slot].set(jnp.asarray(one["pos"], jnp.int32))
    return {"caches": new_caches, "pos": pos}
