from repro.models import layers, pim, ssm, transformer  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_decode_state,
    init_params,
    lm_loss,
    param_specs,
    prefill,
)
