"""PIM-backed model execution: the compile-step facade for whole LMs.

The actual compiler lives in ``repro.models.pim_compile``: it runs the
paper's Algorithm 1 once per *projection site* (per pattern position, per
repeat, per MoE expert, plus the LM head) and returns a
:class:`~repro.models.pim_compile.CompiledPim` — the plan pytree that rides
the layer scans next to the params, the matching logical sharding specs,
and the per-site :class:`~repro.models.pim_compile.SitePlan` architecture
table (chosen slicing, measured error, energy report).

``prepare_pim_params(params, cfg, calib_tokens)`` is the stable 2-tuple
surface the serve engines and launchers consume: ``(plans, specs)``. Use
``pim_compile.compile_pim_params`` directly when you also want the site
table (e.g. to print the slicing histogram or the Titanium-Law report).

Plan leaves are plain dicts of arrays (scan/vmap-friendly). Per-site
decisions — weight slicing above all — ride *inside* the plan leaves
(``slice_shifts`` + ``slice_valid`` padded to the site's max slice count);
``cfg.pim_weight_slicing`` is only an input to the compile step, never read
at dispatch time. Truly global statics (ADC resolution, speculation) stay
on ``ArchConfig`` and are rebuilt at dispatch by
``repro.models.layers.pim_matmul``.

rwkv blocks stay float: their time-mix path is dominated by token-shift
lerps and the LoRA decay (not crossbar-shaped static matmuls); plan
entries for rwkv pattern positions are ``None``, which scans as an empty
pytree.
"""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.pim_compile import (
    CompiledPim,
    SitePlan,
    compile_pim_params,
    plan_specs,
)

__all__ = ["CompiledPim", "SitePlan", "compile_pim_params",
           "plan_specs", "prepare_pim_params"]


def prepare_pim_params(params: dict, cfg: ArchConfig,
                       calib_tokens) -> tuple[dict | None, dict | None]:
    """Compile ``params`` into a PIM plan pytree for ``cfg.pim_mode``.

    calib_tokens: (B, S) int32 token ids (or (B, S, D) embeds for
    embedding-mode archs) used for activation-range calibration and — with
    ``cfg.pim_weight_slicing == "adaptive"`` — the per-site Algorithm-1
    slicing search. Returns ``(plans, specs)``: ``plans`` mirrors the
    consuming call signature (``plans["blocks"][i]`` rides the layer
    scans, ``plans["embed"]["head"]`` the LM head); ``specs`` holds
    logical sharding axes per leaf (``plan_specs``). Mode 'off' returns
    ``(None, None)`` — the float path needs no compile step.
    """
    compiled = compile_pim_params(params, cfg, calib_tokens)
    if compiled is None:
        return None, None
    return compiled.plans, compiled.specs
