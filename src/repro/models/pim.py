"""PIM-backed model execution: the paper's compile step for whole LMs.

``prepare_pim_params(params, cfg, calib_tokens)`` runs Algorithm 1 once
per weight-static projection (qkv/o, dense FFN, MoE experts, mamba
in/x/out, lm_head) and returns a *plan pytree* that rides alongside the
params through ``forward`` / ``prefill`` / ``prefill_chunk`` /
``decode_step`` — the layer scans carry the stacked plans next to the
stacked params, and ``repro.models.layers.pim_matmul`` dispatches each
projection through ``cfg.pim_mode`` (see that docstring for the modes).

The compile step has two phases:

1. *capture* — an eager, unrolled float forward over the calibration
   tokens with ``PimTap`` recorders standing in for plan leaves, so each
   projection is calibrated on exactly the activations the real forward
   feeds it (per repeat, per expert);
2. *prepare* — for ``fast``/``int8``, ``quant.calibrate_layer`` +
   ``quant.quantize_weights_centered`` vmapped over the ``lax.scan``-
   stacked repeat axis (and the expert axis for MoE); for ``exact``,
   ``pim_linear.prepare`` (Center+Offset encode via Eq. 2) per layer —
   the numpy center search cannot vmap, and exact mode is small-models-
   only by contract.

Plan leaves are plain dicts of arrays (scan/vmap-friendly); everything
static — weight slicing, ADC resolution, speculation — lives on
``ArchConfig`` (``pim_*`` fields) and is rebuilt at dispatch time.
``plan_specs`` mirrors the plan pytree with logical sharding axes so the
int8 offset planes keep the same ``dist`` layout as the float weights
they replace.

rwkv blocks stay float: their time-mix path is dominated by token-shift
lerps and the LoRA decay (not crossbar-shaped static matmuls); plan
entries for rwkv pattern positions are ``None``, which scans as an empty
pytree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import adc as adc_lib
from repro.core import pim_linear
from repro.models import layers as L
from repro.models import transformer as T
from repro.quant import quantize as q

_CORE_PROJ = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mamba": ("in_proj", "x_proj", "out_proj"),
}
_FFN_PROJ = ("w1", "w3", "w2")


def _block_projections(cfg: ArchConfig, i: int) -> dict | None:
    """Weight-static projection names for pattern position ``i`` (grouped
    by param subtree), or None for rwkv (float path)."""
    kind = cfg.block_pattern[i]
    if kind not in _CORE_PROJ:
        return None
    return {"core": _CORE_PROJ[kind], "ffn": _FFN_PROJ}


def _build_taps(cfg: ArchConfig) -> dict:
    blocks = []
    for i in range(len(cfg.block_pattern)):
        paths = _block_projections(cfg, i)
        if paths is None:
            blocks.append(None)
            continue
        blocks.append({g: {n: L.PimTap() for n in names}
                       for g, names in paths.items()})
    return {"embed": {"head": L.PimTap()}, "blocks": blocks}


def _capture(params: dict, cfg: ArchConfig, calib_tokens, taps: dict) -> None:
    """Eager float forward that feeds every tap its projection inputs.

    Unrolled over repeats (no ``lax.scan``) so the taps see concrete
    per-repeat values rather than tracers.
    """
    x = T.embed_inputs(params, cfg, jnp.asarray(calib_tokens))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    for r in range(cfg.n_repeats):
        for i, kind in enumerate(cfg.block_pattern):
            bp = jax.tree.map(lambda a, _r=r: a[_r], params["blocks"][i])
            x = T._apply_block(kind, i, bp, cfg, x, positions,
                               plan=taps["blocks"][i])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    L.lm_head(params["embed"], cfg, x, plan=taps["embed"]["head"])


def _fast_prepare_2d(w: jnp.ndarray, x_cal: jnp.ndarray) -> dict:
    """One layer's fast-path plan: symmetric per-channel int8 (the
    reference quantizer) + centered asymmetric int8 (Eq. 1 operands)."""
    w = w.astype(jnp.float32)
    lq, w_q = q.calibrate_layer(w, x_cal, signed_inputs=True)
    w_off, centers, scale = q.quantize_weights_centered(w)
    return {"w_off": w_off, "centers": centers, "scale": scale,
            "w_q": w_q, "w_scale": lq.w_scale, "x_scale": lq.x_scale}


def _exact_prepare_2d(w, x_cal, cfg: ArchConfig) -> dict:
    plan = pim_linear.prepare(
        jnp.asarray(w, jnp.float32), jnp.asarray(x_cal),
        weight_slicing=cfg.pim_weight_slicing,
        adc=adc_lib.ADCConfig(bits=cfg.pim_adc_bits, signed=True),
        speculation=cfg.pim_speculation, signed_inputs=True)
    return {"planes": jnp.asarray(plan.enc.planes),
            "enc_centers": jnp.asarray(plan.enc.centers),
            "w_q": jnp.asarray(plan.w_q),
            "w_scale": jnp.asarray(plan.lq.w_scale),
            "x_scale": jnp.asarray(plan.lq.x_scale)}


def _prepare_site(w, x_cal, cfg: ArchConfig, stack_dims: int) -> dict:
    """Compile one projection site. ``stack_dims`` leading axes of ``w``
    and ``x_cal`` are mapped over (0: lm_head, 1: repeats, 2: repeats x
    experts)."""
    if cfg.pim_mode in ("fast", "int8"):
        fn = _fast_prepare_2d
        for _ in range(stack_dims):
            fn = jax.vmap(fn)
        return fn(jnp.asarray(w, jnp.float32), jnp.asarray(x_cal))
    if stack_dims == 0:
        return _exact_prepare_2d(w, x_cal, cfg)
    subs = [_prepare_site(w[r], x_cal[r], cfg, stack_dims - 1)
            for r in range(w.shape[0])]
    return jax.tree.map(lambda *a: jnp.stack(a), *subs)


def prepare_pim_params(params: dict, cfg: ArchConfig,
                       calib_tokens) -> tuple[dict | None, dict | None]:
    """Compile ``params`` into a PIM plan pytree for ``cfg.pim_mode``.

    calib_tokens: (B, S) int32 token ids (or (B, S, D) embeds for
    embedding-mode archs) used for activation-range calibration.
    Returns ``(plans, specs)``: ``plans`` mirrors the consuming call
    signature (``plans["blocks"][i]`` rides the layer scans,
    ``plans["embed"]["head"]`` the LM head); ``specs`` holds logical
    sharding axes per leaf (``plan_specs``). Mode 'off' returns
    ``(None, None)`` — the float path needs no compile step.
    """
    if cfg.pim_mode == "off":
        return None, None
    if cfg.pim_mode not in ("fast", "exact", "int8"):
        raise ValueError(f"unknown pim_mode {cfg.pim_mode!r}")
    taps = _build_taps(cfg)
    _capture(params, cfg, calib_tokens, taps)

    blocks = []
    for i in range(len(cfg.block_pattern)):
        paths = _block_projections(cfg, i)
        if paths is None:
            blocks.append(None)
            continue
        bplan = {}
        for group, names in paths.items():
            expert = group == "ffn" and cfg.moe_layer(i)
            bplan[group] = {}
            for name in names:
                tap = taps["blocks"][i][group][name]
                x_cal = np.stack(tap.x)  # (n_repeats, [E,] N, d_in)
                bplan[group][name] = _prepare_site(
                    params["blocks"][i][group][name], x_cal, cfg,
                    stack_dims=2 if expert else 1)
        blocks.append(bplan)
    head = _prepare_site(params["embed"]["head"],
                         taps["embed"]["head"].x[0], cfg, stack_dims=0)
    return {"embed": {"head": head}, "blocks": blocks}, plan_specs(cfg)


# ------------------------------------------------------------------ specs
def _site_specs(ws: tuple, mode: str) -> dict:
    """Plan-leaf logical axes derived from one weight's spec tuple.

    ``ws`` ends with (in_axis, out_axis); leading entries are stack axes
    (repeat ``None`` and/or ``experts``). The int8 offset planes keep the
    float weight's layout; per-column terms keep the output axis.
    """
    lead, out_ax = ws[:-2], ws[-1]
    common = {"w_q": ws, "w_scale": lead + (out_ax,), "x_scale": lead}
    if mode in ("fast", "int8"):
        return dict(common, w_off=ws, centers=lead + (out_ax,),
                    scale=lead + (out_ax,))
    # exact: planes (n_slices, n_seg, rows_per_xbar, cols) per layer
    return dict(common, planes=lead + (None, None, None, out_ax),
                enc_centers=lead + (None, out_ax))


def plan_specs(cfg: ArchConfig) -> dict | None:
    """Logical sharding axes mirroring ``prepare_pim_params``'s plans."""
    if cfg.pim_mode == "off":
        return None
    pspecs = T.param_specs(cfg)
    blocks = []
    for i in range(len(cfg.block_pattern)):
        paths = _block_projections(cfg, i)
        if paths is None:
            blocks.append(None)
            continue
        blocks.append({
            g: {n: _site_specs(tuple(pspecs["blocks"][i][g][n]),
                               cfg.pim_mode)
                for n in names}
            for g, names in paths.items()})
    head = _site_specs(tuple(pspecs["embed"]["head"]), cfg.pim_mode)
    return {"embed": {"head": head}, "blocks": blocks}
