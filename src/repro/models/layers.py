"""Transformer building blocks, pure JAX.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with tuples of *logical* sharding axes (resolved by
``repro.dist.sharding``). Attention is chunked (online softmax over KV
blocks) so no S x S score tensor ever materializes — required for the 32k
prefill shapes — plus a single-query flash-decode path that keeps the KV
cache's sequence sharding intact. MoE uses GShard-style sub-grouped one-hot
einsum dispatch (no scatter/gather: GSPMD partitions everything) with
experts sharded over the model axis.
"""

from __future__ import annotations

import contextlib
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import adc as adc_lib
from repro.core import backends as device_backends
from repro.core import center_offset as co
from repro.core import pim_linear
from repro.dist import shard
from repro.quant import quantize as quantlib

ATTN_CHUNK = 512


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ pim
# Trace-time work-stats collector (sow-style). ``collect_pim_stats()``
# pushes a sink; while one is active, every exact-mode ``pim_matmul``
# records its per-pass SpeculationStats / CrossbarStats into the
# *innermost* sink at trace time. Tracer hygiene: values created inside
# a ``lax.scan`` body belong to that sub-trace and must not leak to an
# outer sink — ``transformer.decode_step`` therefore opens its own sink
# inside the scanned block body and re-emits the summed totals as scan
# outputs (see its ``repeat_body``); other scanned/vmapped regions
# (prefill bodies, MoE expert vmap) *suspend* collection instead, so the
# collector reports decode-step work (the serve-time converts/token
# metric) plus any non-scanned projections.
_PIM_STATS_SINKS: list[list] = []

# total-able work-stat fields; ``conversions_possible`` is the static
# path's name for the no-speculation baseline
PIM_STAT_KEYS = ("adc_converts", "no_spec_converts", "spec_failures",
                 "spec_attempts", "recovery_saturations", "cycles", "macs")
_STAT_ALIASES = {"no_spec_converts": "conversions_possible"}


@contextlib.contextmanager
def collect_pim_stats():
    """Collect exact-path work stats from every ``pim_matmul`` traced in
    the body. Yields the sink list: raw stats objects and/or totals
    dicts (from scanned regions). Reduce with ``pim_stats_totals``."""
    sink: list = []
    _PIM_STATS_SINKS.append(sink)
    try:
        yield sink
    finally:
        _PIM_STATS_SINKS.remove(sink)


@contextlib.contextmanager
def suspend_pim_stats():
    """Mask all active sinks (scan/vmap bodies whose tracers must not
    escape into them)."""
    saved = _PIM_STATS_SINKS[:]
    _PIM_STATS_SINKS.clear()
    try:
        yield
    finally:
        _PIM_STATS_SINKS.extend(saved)


def pim_stats_active() -> bool:
    return bool(_PIM_STATS_SINKS)


def pim_stats_record(entry) -> None:
    """Append a stats object / totals dict to the innermost active sink."""
    if _PIM_STATS_SINKS:
        _PIM_STATS_SINKS[-1].append(entry)


def pim_stats_totals(stats) -> dict:
    """Sum a sink's entries into one ``{field: total}`` dict.

    Entries are SpeculationStats / CrossbarStats objects (exact-path
    per-pass stats) or dicts (pre-summed scan totals). Static fields
    stay exact Python ints; traced fields sum as arrays.
    """
    tot = dict.fromkeys(PIM_STAT_KEYS, 0)
    for st in stats:
        for k in PIM_STAT_KEYS:
            if isinstance(st, dict):
                v = st.get(k, 0)
            else:
                v = getattr(st, k, None)
                if v is None:
                    v = getattr(st, _STAT_ALIASES.get(k, k), 0)
            tot[k] = tot[k] + v
    return tot


def with_pim_stats(fn):
    """Wrap a traced function so it also returns summed work totals.

    ``fn``'s body runs under :func:`collect_pim_stats`; the wrapper
    appends the :func:`pim_stats_totals` dict to ``fn``'s return (tuple
    returns are extended, single returns become a pair). Jit the
    *wrapped* function — the totals then ride the jitted call as
    auxiliary outputs and can join the caller's existing
    ``jax.device_get`` (the serve engines fetch them with the same host
    sync that surfaces the logits, so stats collection adds no extra
    device round-trips).
    """
    def wrapped(*args, **kwargs):
        with collect_pim_stats() as acc:
            out = fn(*args, **kwargs)
            totals = pim_stats_totals(acc)
        if isinstance(out, tuple):
            return out + (totals,)
        return out, totals
    return wrapped


class PimTap:
    """Calibration recorder: stands in for a plan leaf during the capture
    forward of ``repro.models.pim.prepare_pim_params``. ``pim_matmul``
    records the projection's input activations and runs the float path, so
    calibration sees exactly the activations the real forward produces."""

    def __init__(self):
        self.x: list[np.ndarray] = []

    def record(self, x2d: jnp.ndarray) -> None:
        self.x.append(np.asarray(x2d, np.float32))


def _plan_to_pim_plan(plan: dict, cfg: ArchConfig, rows: int) -> pim_linear.PimPlan:
    """Rebuild a ``pim_linear.PimPlan`` from a plan-leaf dict + static cfg.

    Plan leaves carry only arrays (so they ride ``lax.scan`` / ``vmap``
    over the stacked block axis). The weight slicing is *per site*: exact
    plan leaves carry their own ``slice_shifts`` / ``slice_valid`` tables
    (padded to the site's max slice count by the compiler —
    ``repro.models.pim_compile``); ``cfg.pim_weight_slicing`` is never
    read here. Truly global statics — ADC resolution, speculation — are
    reconstructed from ``cfg``.
    """
    lq = quantlib.LayerQuant(
        w_scale=plan["w_scale"], x_scale=plan["x_scale"],
        x_zero_point=jnp.asarray(0, jnp.int32), x_signed=True,
        out_scale=jnp.asarray(1.0, jnp.float32),
        out_zero_point=jnp.asarray(0, jnp.int32), bias=None)
    enc = None
    if "planes" in plan:
        # zero padded slice planes so correctness never depends on what the
        # compiler stored beyond each instance's true slice count
        valid = plan["slice_valid"]
        planes = plan["planes"] * valid[:, None, None, None].astype(
            plan["planes"].dtype)
        enc = co.EncodedWeights(
            planes=planes, centers=plan["enc_centers"],
            slicing=None, shifts=plan["slice_shifts"].astype(jnp.int32),
            rows=rows, rows_per_xbar=co.ROWS_PER_CROSSBAR)
    return pim_linear.PimPlan(
        enc=enc, lq=lq, w_q=plan["w_q"], weight_slicing=None,
        adc=adc_lib.ADCConfig(bits=cfg.pim_adc_bits, signed=True),
        speculation=cfg.pim_speculation,
        kernel_backend=cfg.pim_kernel_backend,
        device=device_backends.make(cfg.pim_crossbar_backend,
                                    cfg.pim_device_corner,
                                    seed=cfg.pim_device_seed),
        fast_w_off=plan.get("w_off"), fast_centers=plan.get("centers"),
        fast_scale=plan.get("scale"))


def pim_matmul(x: jnp.ndarray, w: jnp.ndarray, plan,
               cfg: ArchConfig) -> jnp.ndarray:
    """One weight-static projection, routed through ``cfg.pim_mode``.

    ``x (..., R) @ w (R, C)``. ``plan`` is this projection's compiled leaf
    from ``repro.models.pim.prepare_pim_params`` (``None`` -> float path:
    training, rwkv blocks, or ``pim_mode == 'off'``). Modes:

      fast  — centered int8 MXU matmul (paper Eq. 1; Pallas kernel when
              ``cfg.pim_use_pallas``, XLA fallback otherwise).
      exact — bit-exact accelerator simulation (Center+Offset, sliced
              crossbars, ADC, speculation) via ``pim_linear.forward_exact``.
      int8  — ideal 8b-quantized reference (``forward_int_reference``);
              the dequant oracle ``exact`` matches bit-for-bit at a
              non-saturating ADC.
    """
    if isinstance(plan, PimTap):
        plan.record(x.reshape(-1, x.shape[-1]))
        plan = None
    if plan is None or cfg.pim_mode == "off":
        return jnp.einsum("...r,rc->...c", x, w)
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    pp = _plan_to_pim_plan(plan, cfg, rows=w.shape[0])
    if cfg.pim_mode == "fast":
        y = pim_linear.forward_fast(xb, pp, use_pallas=cfg.pim_use_pallas)
    elif cfg.pim_mode == "exact":
        if pim_stats_active():
            y, st = pim_linear.forward_exact(xb, pp, return_stats=True)
            for s in st:
                pim_stats_record(s)
        else:
            y = pim_linear.forward_exact(xb, pp)
    elif cfg.pim_mode == "int8":
        y = pim_linear.forward_int_reference(xb, pp)
    else:
        raise ValueError(f"unknown pim_mode {cfg.pim_mode!r}")
    return y.reshape(lead + (w.shape[-1],)).astype(x.dtype)


def plan_leaf(plans, key: str):
    """``plans[key]`` tolerating an absent plan tree (float path)."""
    return None if plans is None else plans.get(key)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


# ------------------------------------------------------------------ norms
def init_rmsnorm(d: int, dtype) -> tuple[dict, dict]:
    return ({"scale": jnp.ones((d,), dtype)}, {"scale": (None,)})


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    # variance accumulated in f32 via the dot unit; the apply stays in the
    # input dtype. (A bare astype(f32) of the block input gets hoisted out
    # of the XLA while-loop, materializing an f32 copy of every saved
    # residual layer at once.)
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * params["scale"]


# ------------------------------------------------------------------ rope
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention
def init_attention(key, cfg: ArchConfig) -> tuple[dict, dict]:
    """QKV/O projections stored 2D with the (heads x head_dim) axis merged:
    the merged axis is always divisible by the model-axis size (40 heads x
    128 = 5120 splits 16 ways even though 40 heads do not)."""
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dt) * sc,
        "wk": jax.random.normal(ks[1], (d, k * hd), dt) * sc,
        "wv": jax.random.normal(ks[2], (d, k * hd), dt) * sc,
        "wo": jax.random.normal(ks[3], (h * hd, d), dt) * (h * hd) ** -0.5,
    }
    s = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"),
        "wo": ("heads", "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((k * hd,), dt)
        p["bv"] = jnp.zeros((k * hd,), dt)
        s["bq"] = ("heads",)
        s["bk"] = ("kv_heads",)
        s["bv"] = ("kv_heads",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    return p, s


def qkv_project(params: dict, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray, plans=None):
    """x (B, S, D) -> q (B,S,H,hd), k/v (B,S,K,hd), RoPE applied."""
    B, S, _ = x.shape
    nh, nk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = pim_matmul(x, params["wq"], plan_leaf(plans, "wq"), cfg)
    k = pim_matmul(x, params["wk"], plan_leaf(plans, "wk"), cfg)
    v = pim_matmul(x, params["wv"], plan_leaf(plans, "wv"), cfg)
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, nh, hd)
    k = k.reshape(B, S, nk, hd)
    v = v.reshape(B, S, nk, hd)
    if cfg.qk_norm:
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_positions: jnp.ndarray,
                      kv_len: jnp.ndarray | int,
                      causal: bool,
                      chunk: int = ATTN_CHUNK) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks (flash-style, exact).

    q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H = K * G.
    q_positions: (B, Sq) global positions of the queries (causal masking).
    kv_len: number of valid KV entries (int or (B,) — masks cache padding).
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    if Sq == 1:
        # decode fast path: one query — no chunk scan. Keeps the KV cache's
        # sequence sharding intact (a scan would slice the sharded seq dim
        # per step, forcing GSPMD to all-gather the whole cache every
        # chunk); the softmax over the sharded seq dim lowers to partial
        # max/sum + a tiny (B,H) all-reduce — flash-decode semantics.
        kv_len_ = jnp.asarray(kv_len, jnp.int32)
        if kv_len_.ndim == 0:
            kv_len_ = jnp.broadcast_to(kv_len_[None], (B,))
        qg1 = q.reshape(B, K, G, D).astype(jnp.float32)
        s = jnp.einsum("bkgd,bckd->bkgc", qg1,
                       k.astype(jnp.float32)) * (D ** -0.5)
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] < kv_len_[:, None]          # (B, Sk)
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgc,bckd->bkgd", p, v.astype(jnp.float32))
        return out.reshape(B, 1, H, D).astype(q.dtype)
    nk = -(-Sk // chunk)
    pad = nk * chunk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(kp.reshape(B, nk, chunk, K, D), 1, 0)
    vc = jnp.moveaxis(vp.reshape(B, nk, chunk, K, D), 1, 0)
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    scale = D ** -0.5
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.broadcast_to(kv_len[None], (B,))

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32)) * scale
        kpos = ci * chunk + jnp.arange(chunk)  # (chunk,)
        valid = kpos[None, :] < kv_len[:, None]  # (B, chunk)
        mask = valid[:, None, None, None, :]
        if causal:
            cm = kpos[None, None, :] <= q_positions[:, :, None]  # (B, Sq, chunk)
            mask = mask & cm[:, :, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, K, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    # flash-style backward: recompute scores/probs per chunk instead of
    # saving (B, Sq, K, G, chunk) residuals for every chunk step
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_block(params: dict, cfg: ArchConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, plans=None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = qkv_project(params, cfg, x, positions, plans)
    q = shard(q, "batch", "seq", None, None)
    out = chunked_attention(q, k, v, q_positions=positions, kv_len=S,
                            causal=cfg.causal)
    return pim_matmul(out.reshape(B, S, -1), params["wo"],
                      plan_leaf(plans, "wo"), cfg)


# ------------------------------------------------------------------ mlp
def init_mlp(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "w1": jax.random.normal(ks[0], (d, f), dt) * d ** -0.5,   # gate
        "w3": jax.random.normal(ks[1], (d, f), dt) * d ** -0.5,   # up
        "w2": jax.random.normal(ks[2], (f, d), dt) * f ** -0.5,   # down
    }
    s = {"w1": ("fsdp", "tp"), "w3": ("fsdp", "tp"), "w2": ("tp", "fsdp")}
    return p, s


def mlp_block(params: dict, cfg: ArchConfig, x: jnp.ndarray,
              plans=None) -> jnp.ndarray:
    a = act_fn(cfg.activation)
    h = a(pim_matmul(x, params["w1"], plan_leaf(plans, "w1"), cfg)) \
        * pim_matmul(x, params["w3"], plan_leaf(plans, "w3"), cfg)
    h = shard(h, "batch", "seq", "tp")
    return pim_matmul(h, params["w2"], plan_leaf(plans, "w2"), cfg)


# ------------------------------------------------------------------ moe
def init_moe(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5,
        "w1": jax.random.normal(ks[1], (e, d, f), dt) * d ** -0.5,
        "w3": jax.random.normal(ks[2], (e, d, f), dt) * d ** -0.5,
        "w2": jax.random.normal(ks[3], (e, f, d), dt) * f ** -0.5,
    }
    s = {"router": (None, None),
         "w1": ("experts", "fsdp", "tp"),
         "w3": ("experts", "fsdp", "tp"),
         "w2": ("experts", "tp", "fsdp")}
    return p, s


def _moe_group_size(E: int) -> int:
    """Dispatch-group length (slots): large enough that per-group expert
    capacity is not over-quantized, small enough that the one-hot dispatch
    tensor stays a few percent of expert compute."""
    return 1024 if E >= 64 else 512


def _expert_matmul(x5: jnp.ndarray, w3: jnp.ndarray, plan,
                   cfg: ArchConfig, spec: str) -> jnp.ndarray:
    """Per-expert projection: ``x5`` with an expert axis at dim 2 contracted
    against ``w3 (E, d_in, d_out)``. ``plan`` leaves carry a leading expert
    axis; the 2D pim path is vmapped over it (each expert is its own
    crossbar-programmed layer)."""
    if isinstance(plan, PimTap):
        plan.record(jnp.moveaxis(x5, 2, 0).reshape(
            x5.shape[2], -1, x5.shape[-1]))
        plan = None
    if plan is None or cfg.pim_mode == "off":
        return jnp.einsum(spec, x5, w3)
    xt = jnp.moveaxis(x5, 2, 0)  # (E, B, nG, cap, d_in)
    # stats stay suspended under the expert vmap: batched tracers must
    # not leak into an outer sink (converts/token reporting covers dense
    # projections; per-expert billing is a ROADMAP follow-on)
    with suspend_pim_stats():
        yt = jax.vmap(lambda xe, we, pe: pim_matmul(xe, we, pe, cfg))(
            xt, w3, plan)
    return jnp.moveaxis(yt, 0, 2)


def moe_block(params: dict, cfg: ArchConfig, x: jnp.ndarray,
              plans=None) -> jnp.ndarray:
    """Top-k capacity-based MoE, EP over 'experts' (GShard-style).

    Dispatch and combine are *one-hot einsums over sub-groups of slots* —
    no scatter/gather anywhere, so GSPMD partitions everything (batch x
    seq-groups x experts) and the only data movement is the all-to-all
    class resharding around the expert einsums. The dispatch tensor is
    (B, groups, g, E, cap_g): a few percent of expert FLOPs/bytes.
    """
    B, S, D = x.shape
    if S == 1 and B > 1:
        # decode: merge the batch into one dispatch group — per-token groups
        # would give every token a private (E x cap) buffer, i.e. dense
        # compute over all experts for one active row each (E-fold waste)
        out = moe_block(params, cfg, x.reshape(1, B, D), plans)
        return out.reshape(B, 1, D)
    E, k = cfg.n_experts, cfg.experts_per_token
    a = act_fn(cfg.activation)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    vals, idx = jax.lax.top_k(logits, k)                # (B, S, k)
    if k == 1:
        weights = jax.nn.sigmoid(vals)                  # llama4-style gate
    else:
        weights = jax.nn.softmax(vals, axis=-1)

    slots = S * k
    g = min(_moe_group_size(E), slots)
    nG = -(-slots // g)
    pad = nG * g - slots
    cap = max(k, int(math.ceil(cfg.capacity_factor * g / E)))

    fe = idx.reshape(B, slots)
    fw = weights.reshape(B, slots).astype(x.dtype)
    xr = jnp.repeat(x, k, axis=1)                        # (B, slots, D)
    if pad:
        fe = jnp.pad(fe, ((0, 0), (0, pad)), constant_values=E)  # E = none
        fw = jnp.pad(fw, ((0, 0), (0, pad)))
        xr = jnp.pad(xr, ((0, 0), (0, pad), (0, 0)))
    fe = fe.reshape(B, nG, g)
    fw = fw.reshape(B, nG, g)
    xg = xr.reshape(B, nG, g, D)
    xg = shard(xg, "batch", "seq", None, None)

    # ranks in f32: group length can exceed bf16's exact-integer range
    eh32 = jax.nn.one_hot(fe, E, dtype=jnp.float32)      # (B, nG, g, E)
    ranks = jnp.cumsum(eh32, axis=2) - eh32              # rank within expert
    pos = jnp.einsum("bnge,bnge->bng", ranks, eh32).astype(jnp.int32)
    eh = eh32.astype(x.dtype)
    keep = (pos < cap).astype(x.dtype)
    ph = jax.nn.one_hot(pos, cap, dtype=x.dtype)         # (B, nG, g, cap)
    dispatch = eh[..., :, None] * ph[..., None, :] \
        * keep[..., None, None]                          # (B, nG, g, E, cap)
    dispatch = shard(dispatch, "batch", None, None, "experts", None)

    buf = jnp.einsum("bngec,bngd->bnecd", dispatch, xg)  # (B, nG, E, cap, D)
    buf = shard(buf, "batch", None, "experts", None, None)
    h = a(_expert_matmul(buf, params["w1"], plan_leaf(plans, "w1"), cfg,
                         "bnecd,edf->bnecf")) \
        * _expert_matmul(buf, params["w3"], plan_leaf(plans, "w3"), cfg,
                         "bnecd,edf->bnecf")
    h = shard(h, "batch", None, "experts", None, "tp")
    y = _expert_matmul(h, params["w2"], plan_leaf(plans, "w2"), cfg,
                       "bnecf,efd->bnecd")
    y = shard(y, "batch", None, "experts", None, None)

    combine = dispatch * fw[..., None, None]
    out = jnp.einsum("bngec,bnecd->bngd", combine, y)    # (B, nG, g, D)
    out = out.reshape(B, nG * g, D)[:, :slots]
    return out.reshape(B, S, k, D).sum(axis=2)


# ------------------------------------------------------------------ embedding
def init_embedding(key, cfg: ArchConfig) -> tuple[dict, dict]:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["embed"] = jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), dt) \
        * cfg.d_model ** -0.5
    s["embed"] = ("vocab", "fsdp")
    p["head"] = jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size), dt) \
        * cfg.d_model ** -0.5
    s["head"] = ("fsdp", "vocab")
    return p, s


def embed(params: dict, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embed"][tokens]


def lm_head(params: dict, cfg: ArchConfig, x: jnp.ndarray,
            plan=None) -> jnp.ndarray:
    logits = pim_matmul(x, params["head"], plan, cfg)
    return shard(logits, "batch", "seq", "vocab")
