"""Continuous-batching serve engine (iteration-level scheduling).

The lockstep ``ServeEngine`` pads every request in a batch to one prompt
length and decodes until the *slowest* request finishes — a slot that
retired early still burns a decode-step's FLOPs (and, under a non-'off'
``cfg.pim_mode``, PIM-path work: both engines thread the compiled plan
pytree from ``repro.models.pim.prepare_pim_params`` — the per-site
architecture compiler, so each projection site runs its own compiled
weight slicing — through every jitted prefill/decode call, so the
weight-static projections actually run the centered-int8 /
exact-simulation path) on padding. RAELLA's economy is
converts per *useful* output, so the serving layer admits and retires
requests independently instead:

- the batched decode state holds ``n_slots`` KV-cache slots with
  *per-slot* positions (``init_decode_state(..., per_slot_pos=True)``);
- each engine iteration admits queued requests into free slots, advances
  at most one *prefill chunk* per prefilling slot (long prompts never
  stall decode for the other slots), then runs one batched
  ``decode_step`` for every slot that is mid-generation;
- a finished request frees its slot immediately; the next queued request
  is spliced in with ``insert_request`` (a batch-axis
  ``dynamic_update_slice``), so the cache sharding (``cache_batch`` under
  ``SERVE_RULES``) is untouched.

Determinism contract: greedy (``temperature == 0``) outputs are
bit-identical to running each request alone through the lockstep engine
— decode math is per-slot independent, and chunked prefill reproduces
whole-prompt prefill for float KV caches (see ``prefill_chunk``). One
carve-out: MoE decode merges the batch into a single dispatch group
(``moe_block``), so if any token hits expert capacity the drop pattern
depends on batch composition — including the garbage tokens idle or
mid-prefill slots feed through decode — and *any* batched run (lockstep
or continuous) can diverge from the solo run. The contract therefore
holds for MoE configs only while nothing hits capacity; the reduced
smoke configs pin ``capacity_factor`` high enough to guarantee that,
and production MoE serving should size ``capacity_factor`` (or group
size) for drop-free decode. Sampled requests replay the lockstep
per-request stream: ``key(seed)`` for the first token,
``fold_in(key(seed), i)`` for decode step ``i`` — temperature-0
requests never touch a PRNG key.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.obs.serve import NULL_TELEMETRY, ServeTelemetry


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request with its own sampling/stop parameters."""
    uid: int
    prompt: np.ndarray                     # (prompt_len,) int32 token ids
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    stop_tokens: tuple[int, ...] = ()      # stop after emitting any of these


@dataclasses.dataclass
class RequestOutput:
    uid: int
    prompt_len: int
    tokens: np.ndarray                     # (n_generated,) int32, includes
    finish_reason: str                     # the stop token if one fired
                                           # "stop" | "length"


@dataclasses.dataclass
class ServeStats:
    decode_steps: int = 0                  # batched decode_step calls
    decode_slot_tokens: int = 0            # useful tokens over those calls
    prefill_chunks: int = 0
    completed: int = 0
    # block-pool accounting (paged engine — repro.serve.paged; zero on
    # the contiguous engine, whose per-slot regions are never shared)
    blocks_in_use: int = 0                 # current pool occupancy
    peak_blocks_in_use: int = 0
    evictions: int = 0                     # preempt-by-recompute events
    prefix_block_hits: int = 0             # shared-prefix blocks reused
    admission_waits: int = 0               # iterations head-of-queue waited

    @property
    def decode_utilization(self) -> float:
        """Average useful (non-padding) tokens per decode step.

        Absolute tokens/step in ``[0, n_slots]`` — divide by the
        engine's ``n_slots`` for a 0..1 fraction (as
        ``benchmarks/serve_continuous.py`` does). A fresh engine
        (``decode_steps == 0``) reports 0.0, never a division error."""
        return 0.0 if self.decode_steps == 0 else (
            self.decode_slot_tokens / self.decode_steps)

    def snapshot(self) -> dict:
        """Every counter plus the derived utilization, as plain scalars.

        This is the ONE stats schema both engines expose — the paged
        engine shares this dataclass rather than growing its own, so
        exporters (``repro.obs``), benchmarks, and the serve launcher
        all read the same keys (``tests/test_obs.py`` asserts parity).
        """
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)}
        d["decode_utilization"] = self.decode_utilization
        return d


EngineStats = ServeStats   # back-compat alias (pre-paged-KV name)


@dataclasses.dataclass
class _Slot:
    req: Request
    state1: Any                    # B=1 partial prefill state, until inserted
    n_prefilled: int = 0
    tokens: list = dataclasses.field(default_factory=list)
    next_tok: int = 0
    key: Any = None                # PRNG key, only if temperature > 0
    n_sampled: int = 0


class ContinuousServeEngine:
    """Slot-based continuous batching over the jitted prefill/decode.

    All jitted computations have fixed shapes — (n_slots, 1) decode, and
    prefill chunks of ``prefill_chunk`` tokens (plus one shorter
    remainder shape per distinct prompt-length residue), so steady-state
    serving never recompiles.
    """

    def __init__(self, cfg: ArchConfig, params: Any, *, n_slots: int = 4,
                 max_len: int = 512, prefill_chunk: int = 64,
                 plans: Any = None,
                 telemetry: ServeTelemetry | None = None):
        if not cfg.causal:
            raise ValueError(f"{cfg.name} is encoder-only; no decode")
        if n_slots < 1 or prefill_chunk < 1:
            raise ValueError("n_slots and prefill_chunk must be >= 1")
        if cfg.pim_mode != "off" and plans is None:
            raise ValueError(
                f"pim_mode={cfg.pim_mode!r} needs compiled plans — call "
                "repro.models.pim.prepare_pim_params(params, cfg, "
                "calib_tokens) and pass plans=")
        self.cfg = cfg
        self.params = params
        self.plans = plans
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.state = T.init_decode_state(cfg, n_slots, max_len,
                                         per_slot_pos=True)
        self.slots: list[_Slot | None] = [None] * n_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = ServeStats()
        self.tel = telemetry if telemetry is not None else NULL_TELEMETRY
        self._chunk = jax.jit(
            lambda p, pl, st, toks: T.prefill_chunk(p, cfg, st, toks,
                                                    plans=pl))
        self._decode = jax.jit(self._wrap_decode(
            lambda p, pl, st, tok: T.decode_step(p, cfg, st, tok, plans=pl)))
        self._insert = jax.jit(
            lambda st, one, slot: T.insert_request(st, one, slot))
        # jax arrays are immutable, so one zero template serves every
        # admission (prefill_chunk returns fresh state pytrees)
        self._template1 = T.init_decode_state(cfg, 1, max_len)

    # ---------------------------------------------------------- telemetry
    def _wrap_decode(self, decode_fn):
        """With PIM stats requested (``cfg.pim_mode == 'exact'`` + an
        enabled telemetry), the jitted decode also returns the summed
        work totals (``layers.with_pim_stats`` — the PR 7 scan-safe
        collector), which join the iteration's one ``device_get``.
        Decode *math* is untouched either way: greedy outputs are
        bit-identical with telemetry on or off."""
        self._collect_pim = self.tel.wants_pim_stats(self.cfg)
        if not self._collect_pim:
            return decode_fn
        self.tel.pim_adc_bits = self.cfg.pim_adc_bits
        return L.with_pim_stats(decode_fn)

    def _decode_fetch(self, out, n_live: int):
        """Unpack a decode-jit result: all slot logits (and the PIM work
        totals, when collected) come back in ONE ``jax.device_get`` —
        the same single host sync per iteration as before telemetry."""
        if self._collect_pim:
            logits, state, tot = out
            rows, tot = jax.device_get((logits[:, -1, :], tot))
            self.tel.on_pim_totals({k: int(v) for k, v in tot.items()},
                                   n_live)
        else:
            logits, state = out
            rows = jax.device_get(logits[:, -1, :])
        return rows, state

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        plen = int(np.asarray(req.prompt).shape[0])
        if plen < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens < 1")
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt ({plen}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_len "
                f"({self.max_len})")
        self.queue.append(req)
        self.tel.on_submit(req.uid)

    @property
    def active_uids(self) -> tuple[int, ...]:
        return tuple(s.req.uid for s in self.slots if s is not None)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    # ------------------------------------------------------------- engine
    def _sample(self, slot: _Slot, logits_row: np.ndarray,
                greedy_tok: int) -> int:
        """Pick slot's next token. logits_row: host (vocab,) for this slot
        (the scheduler pulls all slots' logits in ONE ``jax.device_get``
        per iteration — see ``step`` — so the sampling path never adds a
        per-slot sync)."""
        if slot.req.temperature <= 0.0:
            return greedy_tok
        if slot.key is None:
            slot.key = jax.random.key(slot.req.seed)
        key = slot.key if slot.n_sampled == 0 else jax.random.fold_in(
            slot.key, slot.n_sampled - 1)
        slot.n_sampled += 1
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row) / slot.req.temperature))

    def _commit(self, idx: int, slot: _Slot, tok: int,
                finished: list[RequestOutput]) -> None:
        """Record a generated token; retire the slot if the request is done."""
        slot.tokens.append(tok)
        slot.next_tok = tok
        self.tel.on_token(slot.req.uid)
        reason = None
        if tok in slot.req.stop_tokens:
            reason = "stop"
        elif len(slot.tokens) >= slot.req.max_new_tokens:
            reason = "length"
        if reason is not None:
            finished.append(RequestOutput(
                uid=slot.req.uid,
                prompt_len=int(np.asarray(slot.req.prompt).shape[0]),
                tokens=np.asarray(slot.tokens, np.int32),
                finish_reason=reason))
            self.slots[idx] = None
            self.stats.completed += 1
            self.tel.on_finish(slot.req.uid, reason, len(slot.tokens))

    def step(self) -> list[RequestOutput]:
        """One scheduler iteration: admit → prefill one chunk → decode.

        Returns the requests that finished during this iteration. Host
        syncs are batched: all prefill-completion logits come back in one
        ``jax.device_get``, and the decode step pulls every slot's last
        logits row at once (greedy argmax then runs host-side —
        ``np.argmax`` and ``jnp.argmax`` both take the first maximum, so
        the tie-break is bit-identical).
        """
        finished: list[RequestOutput] = []
        # 1. admit queued requests into free slots
        with self.tel.span("admission"):
            for i in range(self.n_slots):
                if self.slots[i] is None and self.queue:
                    req = self.queue.popleft()
                    self.slots[i] = _Slot(req=req, state1=self._template1)
                    self.tel.on_admit(req.uid,
                                      int(np.asarray(req.prompt).shape[0]))
        # 2. advance each prefilling slot by one chunk
        done: list[tuple[int, _Slot, Any]] = []
        for i, slot in enumerate(self.slots):
            if slot is None or slot.state1 is None:
                continue
            prompt = np.asarray(slot.req.prompt, np.int32)
            lo = slot.n_prefilled
            hi = min(lo + self.prefill_chunk, prompt.shape[0])
            with self.tel.span("prefill_chunk", uid=slot.req.uid,
                               lo=lo, hi=hi), \
                    self.tel.annotate_step("prefill_chunk",
                                           self.stats.prefill_chunks):
                logits, slot.state1 = self._chunk(
                    self.params, self.plans, slot.state1,
                    jnp.asarray(prompt[None, lo:hi]))
            slot.n_prefilled = hi
            self.stats.prefill_chunks += 1
            self.tel.on_prefill_chunk(slot.req.uid, lo, hi)
            if hi == prompt.shape[0]:
                # prompt done: splice into the batch; first-token logits
                # are committed below, after ONE batched device_get
                self.state = self._insert(self.state, slot.state1,
                                          jnp.asarray(i, jnp.int32))
                slot.state1 = None
                done.append((i, slot, logits[0, -1]))
        if done:
            rows = jax.device_get([lg for _, _, lg in done])
            for (i, slot, _), row in zip(done, rows):
                self._commit(i, slot,
                             self._sample(slot, row, int(np.argmax(row))),
                             finished)
        # 3. one batched decode step for every mid-generation slot
        live = [i for i, s in enumerate(self.slots)
                if s is not None and s.state1 is None]
        if live:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for i in live:
                toks[i, 0] = self.slots[i].next_tok
            with self.tel.span("decode_step", n_live=len(live)):
                t0 = time.perf_counter()
                with self.tel.annotate_step("decode_step",
                                            self.stats.decode_steps):
                    out = self._decode(self.params, self.plans, self.state,
                                       jnp.asarray(toks))
                rows, self.state = self._decode_fetch(out, len(live))
                self.tel.observe_decode_step_seconds(
                    time.perf_counter() - t0)
                self.stats.decode_steps += 1
                self.stats.decode_slot_tokens += len(live)
                self.tel.on_decode_step(len(live))
                greedy = np.argmax(rows, axis=-1)
                for i in live:
                    slot = self.slots[i]
                    self._commit(i, slot,
                                 self._sample(slot, rows[i],
                                              int(greedy[i])), finished)
        return finished

    def _drain_budget(self) -> int:
        """Iteration cap for ``run`` (the paged engine widens it: evicted
        requests recompute from scratch)."""
        return ((len(self.queue) + len(self.active_uids) + 1)
                * (self.max_len + self.max_len // self.prefill_chunk + 2))

    def run(self, requests: list[Request] | None = None,
            max_iters: int | None = None) -> list[RequestOutput]:
        """Drain: submit ``requests`` and step until everything finishes.

        Outputs are returned ordered by ``uid`` for stable comparison.
        """
        for r in requests or ():
            self.submit(r)
        budget = max_iters if max_iters is not None else self._drain_budget()
        outputs: list[RequestOutput] = []
        it = 0
        while self.has_work:
            if it >= budget:
                raise RuntimeError(
                    f"scheduler did not drain within {budget} iterations")
            outputs.extend(self.step())
            it += 1
        return sorted(outputs, key=lambda o: o.uid)
