"""Batched serving engine: chunked prefill + batched greedy/sampled decode.

The engine owns jitted prefill/decode functions for one (arch, batch,
max_len) bucket and exposes a request-batch API. RAELLA integration:
with ``cfg.pim_mode != 'off'`` the engine requires the compiled plan
pytree from ``repro.models.pim.prepare_pim_params`` (the per-site
architecture compiler, ``repro.models.pim_compile``) and passes it to
every jitted prefill/decode call — 'fast' runs the weight-static
projections on the centered int8 path (the paper's Eq. 1 on the MXU, see
``models.layers.pim_matmul``), 'exact' the bit-exact accelerator
simulation (small models only; each site runs its own compiled weight
slicing), 'int8' the ideal 8b-quantized reference.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, steps) generated ids
    prompt_len: int
    steps: int


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, *,
                 max_len: int = 512, temperature: float = 0.0,
                 plans: Any = None):
        if not cfg.causal:
            raise ValueError(f"{cfg.name} is encoder-only; no decode")
        if cfg.pim_mode != "off" and plans is None:
            raise ValueError(
                f"pim_mode={cfg.pim_mode!r} needs compiled plans — call "
                "repro.models.pim.prepare_pim_params(params, cfg, "
                "calib_tokens) and pass plans=")
        self.cfg = cfg
        self.params = params
        self.plans = plans
        self.max_len = max_len
        self.temperature = temperature
        self._prefill = jax.jit(
            lambda p, pl, toks: T.prefill(p, cfg, toks, max_len=max_len,
                                          plans=pl))
        self._decode = jax.jit(
            lambda p, pl, st, tok: T.decode_step(p, cfg, st, tok, plans=pl))

    def _pick(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        logits = logits[:, -1, :]
        if key is None:
            return jnp.argmax(logits, axis=-1)[:, None]
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1)[:, None]

    def generate(self, prompts: np.ndarray, *, steps: int,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, prompt_len) int32 token ids.

        Seed reproducibility: at ``temperature == 0`` no PRNG key is ever
        created, split, or consumed — greedy outputs are deterministic and
        independent of ``seed``. At ``temperature > 0`` the stream is
        ``jax.random.key(seed)`` for the first token and
        ``fold_in(key(seed), i)`` for decode step ``i``, so a fixed seed
        replays the exact sample sequence (the continuous-batching engine
        uses the same per-request scheme — see repro.serve.scheduler).
        """
        toks = jnp.asarray(prompts, jnp.int32)
        B, plen = toks.shape
        if plen + steps > self.max_len:
            raise ValueError("prompt + steps exceeds engine max_len")
        key = None if self.temperature <= 0.0 else jax.random.key(seed)
        logits, state = self._prefill(self.params, self.plans, toks)
        out = []
        tok = self._pick(logits, key)
        out.append(tok)
        for i in range(steps - 1):
            step_key = None if key is None else jax.random.fold_in(key, i)
            logits, state = self._decode(self.params, self.plans, state, tok)
            tok = self._pick(logits, step_key)
            out.append(tok)
        gen = np.asarray(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=gen, prompt_len=plen, steps=steps)
