"""Serving layer: lockstep reference engine + continuous-batching engine."""

from repro.serve.engine import GenerationResult, ServeEngine  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    ContinuousServeEngine,
    EngineStats,
    Request,
    RequestOutput,
)

__all__ = [
    "ContinuousServeEngine",
    "EngineStats",
    "GenerationResult",
    "Request",
    "RequestOutput",
    "ServeEngine",
]
