"""Serving layer: lockstep reference, continuous batching, paged KV."""

from repro.serve.engine import GenerationResult, ServeEngine  # noqa: F401
from repro.serve.paged import BlockAllocator, PagedServeEngine  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    ContinuousServeEngine,
    EngineStats,
    Request,
    RequestOutput,
    ServeStats,
)

__all__ = [
    "BlockAllocator",
    "ContinuousServeEngine",
    "EngineStats",
    "GenerationResult",
    "PagedServeEngine",
    "Request",
    "RequestOutput",
    "ServeEngine",
    "ServeStats",
]
