"""Paged-KV continuous batching + disaggregated prefill/decode serving.

The contiguous ``ContinuousServeEngine`` reserves one ``max_len`` KV
region per slot up front, so a pod's serving capacity is bounded by
``n_slots * max_len`` tokens of cache *whether or not the requests use
them* — the binding constraint once the PIM datapath runs at kernel
speed. This engine replaces the per-slot regions with a vLLM-style paged
cache:

- **Block pool + tables.** Attention KV lives in one fixed pool of
  ``n_blocks`` blocks of ``block_size`` tokens
  (``models.transformer.PagedLayout``); each slot owns a block *table*.
  Admission claims ``ceil(prompt_len / block_size)`` free blocks instead
  of a whole region — copy-free for attention-only archs, whose prompt
  streams straight into the claimed blocks (``prefill_chunk_paged``).
- **Block-granular free.** A request that stops early returns its blocks
  the same iteration; decode grows a slot one block at a time, so memory
  tracks *actual* lengths, not ``max_len`` worst cases.
- **Prefix sharing.** A fully-written prompt block is registered under
  the bytes of the prompt up to and including it; later admissions share
  the longest common whole-block prefix by refcount (common system
  prompts are stored once while any sharer is live).
- **Queue-until-blocks-free + eviction.** Admission is strict FIFO under
  memory pressure (the head of the queue waits; nothing overtakes it).
  If decode needs a block and the pool is dry, the *youngest* other
  request is evicted — its blocks freed, the request requeued at the
  front — and recomputed later; determinism of both greedy decoding and
  the per-request ``fold_in`` sampling stream makes the recompute replay
  the identical tokens, so eviction never changes outputs.
- **Disaggregated prefill/decode.** With ``prefill_mesh``/``decode_mesh``
  (see ``repro.launch.mesh.make_disaggregated_meshes``) prefill runs on
  its own mesh slice under ``MULTIPOD_SERVE_RULES`` and the finished
  B=1 state is handed to the decode slice, where
  ``insert_request_paged`` scatters it into the slot's pool blocks.
  Params and compiled PIM plan pytrees (weights are PIM-static —
  write-once crossbars) are replicated to both slices at construction.

Greedy outputs stay bit-identical to the contiguous and lockstep engines
(see ``_paged_gather``: masked gather positions contribute exact zeros);
the MoE capacity carve-out of ``repro.serve.scheduler`` applies
unchanged.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist import MULTIPOD_SERVE_RULES, axis_rules
from repro.models import transformer as T
from repro.obs.serve import NULL_TELEMETRY
from repro.serve.scheduler import (
    ContinuousServeEngine,
    RequestOutput,
    ServeStats,
    _Slot,
)


class BlockAllocator:
    """Host-side free list, refcounts, and prefix index over a fixed pool.

    Pool row ``n_blocks`` (the scratch/sentinel row) is never allocated.
    Prefix sharing is hash-chained like vLLM's: block ``j`` of a prompt
    registers under ``prompt[:(j + 1) * block_size].tobytes()``, so a
    lookup walks the chain and shares the longest common *whole-block*
    prefix. Registration lives exactly as long as some request refcounts
    the block — releasing the last reference unregisters it.
    """

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.free: collections.deque[int] = collections.deque(range(n_blocks))
        self.refcount = np.zeros(n_blocks, np.int32)
        self.prefix_index: dict[bytes, int] = {}
        self._block_key: dict[int, bytes] = {}

    @property
    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self.free)

    def prefix_key(self, prompt: np.ndarray, j: int) -> bytes:
        return np.ascontiguousarray(
            prompt[:(j + 1) * self.block_size], dtype=np.int32).tobytes()

    def match_prefix(self, prompt: np.ndarray) -> list[int]:
        """Registered blocks covering the longest whole-block prefix of
        ``prompt`` (non-mutating — claim the result to actually share)."""
        out = []
        for j in range(len(prompt) // self.block_size):
            bid = self.prefix_index.get(self.prefix_key(prompt, j))
            if bid is None:
                break
            out.append(bid)
        return out

    def claim(self, bid: int) -> int:
        """Take a shared reference on an in-use block."""
        assert self.refcount[bid] > 0, "claim() of a free block"
        self.refcount[bid] += 1
        return bid

    def alloc(self, n: int) -> list[int]:
        if n > len(self.free):
            raise RuntimeError(
                f"pool exhausted: want {n}, have {len(self.free)} free")
        out = [self.free.popleft() for _ in range(n)]
        for b in out:
            self.refcount[b] = 1
        return out

    def release(self, bid: int) -> None:
        self.refcount[bid] -= 1
        assert self.refcount[bid] >= 0, "double free"
        if self.refcount[bid] == 0:
            key = self._block_key.pop(bid, None)
            if key is not None:
                self.prefix_index.pop(key, None)
            self.free.append(bid)

    def register(self, bid: int, key: bytes) -> None:
        """Publish a fully-written prompt block for prefix sharing."""
        if key not in self.prefix_index and bid not in self._block_key:
            self.prefix_index[key] = bid
            self._block_key[bid] = key


@dataclasses.dataclass
class _PagedSlot(_Slot):
    blocks: list = dataclasses.field(default_factory=list)  # table order
    n_shared: int = 0              # leading blocks claimed via prefix index
    live: bool = False             # prefill finished, decoding
    host_pos: int = 0              # authoritative position (device pos for
    seq: int = 0                   # mid-prefill slots drifts — see
                                   # prefill_chunk_paged); seq: admission
                                   # order, eviction takes the youngest


class PagedServeEngine(ContinuousServeEngine):
    """Continuous batching over a paged KV pool (+ optional disaggregated
    prefill/decode mesh slices). See the module docstring for semantics;
    the scheduler loop, sampling streams, and stop handling are inherited
    from ``ContinuousServeEngine``.
    """

    def __init__(self, cfg: ArchConfig, params: Any, *, n_slots: int = 4,
                 max_len: int = 512, prefill_chunk: int = 64,
                 block_size: int = 16, n_blocks: int | None = None,
                 prefix_sharing: bool = True, plans: Any = None,
                 prefill_mesh=None, decode_mesh=None, telemetry=None):
        if not cfg.causal:
            raise ValueError(f"{cfg.name} is encoder-only; no decode")
        if n_slots < 1 or prefill_chunk < 1:
            raise ValueError("n_slots and prefill_chunk must be >= 1")
        if cfg.pim_mode != "off" and plans is None:
            raise ValueError(
                f"pim_mode={cfg.pim_mode!r} needs compiled plans — call "
                "repro.models.pim.prepare_pim_params(params, cfg, "
                "calib_tokens) and pass plans=")
        if block_size < 1 or max_len % block_size != 0:
            raise ValueError(
                f"block_size ({block_size}) must be >= 1 and divide "
                f"max_len ({max_len}) — the gathered per-slot view must "
                f"equal one contiguous max_len cache for bit-identity")
        max_blocks = max_len // block_size
        if n_blocks is None:
            n_blocks = n_slots * max_blocks    # no memory pressure
        if n_blocks < max_blocks:
            raise ValueError(
                f"n_blocks ({n_blocks}) under max_len/block_size "
                f"({max_blocks}): one max-length request could never fit "
                f"even after evicting everything else")
        if (prefill_mesh is None) != (decode_mesh is None):
            raise ValueError(
                "pass both prefill_mesh and decode_mesh, or neither")
        self.cfg = cfg
        self.params = params
        self.plans = plans
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.max_blocks = max_blocks
        self.layout = T.PagedLayout(n_blocks=n_blocks, block_size=block_size)
        self.alloc = BlockAllocator(n_blocks, block_size)
        self.prefill_mesh = prefill_mesh
        self.decode_mesh = decode_mesh
        # recurrent carries cannot be rebuilt from paged context, and a
        # disaggregated prefill must not touch the decode slice's pool —
        # both stage at B=1 and hand over via insert_request_paged
        attn_only = all(k == "attn" for k in cfg.block_pattern)
        self.staged_prefill = (not attn_only) or (prefill_mesh is not None)
        # int8 KV quantizes per chunk, so a shared block written under one
        # chunking is not bit-identical under another — no sharing there
        self.prefix_sharing = (prefix_sharing and not self.staged_prefill
                               and cfg.kv_cache_dtype != "int8")
        self.slots: list[_PagedSlot | None] = [None] * n_slots
        self.queue: collections.deque = collections.deque()
        self.stats = ServeStats()
        self.tel = telemetry if telemetry is not None else NULL_TELEMETRY
        self._seq = 0
        # host-authoritative block tables (sentinel = unmapped)
        self.tables = np.full((n_slots, max_blocks), self.layout.sentinel,
                              np.int32)

        layout = self.layout
        self._chunk = jax.jit(
            lambda p, pl, st, toks: T.prefill_chunk(p, cfg, st, toks,
                                                    plans=pl))
        self._chunk_paged = jax.jit(
            lambda p, pl, st, toks, slot, row, pos0: T.prefill_chunk_paged(
                p, cfg, st, toks, slot=slot, table_row=row, pos0=pos0,
                paged=layout, plans=pl))
        self._decode = jax.jit(self._wrap_decode(
            lambda p, pl, st, tok, tb: T.decode_step(
                p, cfg, st, tok, plans=pl, block_tables=tb, paged=layout)))
        self._insert = jax.jit(
            lambda st, one, slot, row: T.insert_request_paged(
                st, one, slot, row, layout))

        state = T.init_decode_state(cfg, n_slots, max_len, per_slot_pos=True,
                                    paged=layout)
        self._template1 = T.init_decode_state(cfg, 1, max_len)
        if decode_mesh is not None:
            P = jax.sharding.PartitionSpec
            rep_p = jax.sharding.NamedSharding(prefill_mesh, P())
            rep_d = jax.sharding.NamedSharding(decode_mesh, P())
            self._params_p = jax.device_put(params, rep_p)
            self._params_d = jax.device_put(params, rep_d)
            self._plans_p = None if plans is None else jax.device_put(
                plans, rep_p)
            self._plans_d = None if plans is None else jax.device_put(
                plans, rep_d)
            self._template1 = jax.device_put(self._template1, rep_p)
            self._rep_d = rep_d
            state = jax.device_put(state, rep_d)
        else:
            self._params_p = self._params_d = params
            self._plans_p = self._plans_d = plans
            self._rep_d = None
        self.state = state

    # ----------------------------------------------------------- helpers
    @contextlib.contextmanager
    def _on(self, mesh):
        """Run under one slice's mesh + the MULTIPOD_SERVE rule set (a
        no-op for single-host paged serving: mesh is None)."""
        if mesh is None:
            yield
        else:
            with mesh, axis_rules(MULTIPOD_SERVE_RULES):
                yield

    def _note_blocks(self) -> None:
        used = self.alloc.blocks_in_use
        self.stats.blocks_in_use = used
        self.stats.peak_blocks_in_use = max(self.stats.peak_blocks_in_use,
                                            used)
        self.tel.on_pool(used, self.stats.peak_blocks_in_use)

    def _drain_budget(self) -> int:
        # evicted requests recompute from scratch; in the worst case each
        # of the other slots' requests preempts a victim once
        return super()._drain_budget() * (1 + self.n_slots)

    # ---------------------------------------------------------- lifecycle
    def _try_admit(self, i: int) -> bool:
        """Admit the queue head into free slot ``i`` if its prompt blocks
        fit (strict FIFO: on a miss the head keeps waiting — nothing
        overtakes it, so admission order is deterministic)."""
        req = self.queue[0]
        prompt = np.asarray(req.prompt, np.int32)
        plen = prompt.shape[0]
        bs = self.layout.block_size
        shared: list[int] = []
        if self.prefix_sharing:
            # cap: at least one prompt token must be prefilled (first-token
            # logits need a forward pass), and decode must never write into
            # a shared block
            shared = self.alloc.match_prefix(prompt)[:(plen - 1) // bs]
        need = self.layout.blocks_for(plen) - len(shared)
        if need > len(self.alloc.free):
            self.stats.admission_waits += 1
            self.tel.on_admission_wait(req.uid)
            return False
        self.queue.popleft()
        blocks = [self.alloc.claim(b) for b in shared] + self.alloc.alloc(need)
        self.stats.prefix_block_hits += len(shared)
        self.tel.on_admit(req.uid, plen)
        if shared:
            self.tel.on_prefix_hits(req.uid, len(shared))
        slot = _PagedSlot(req=req,
                          state1=self._template1 if self.staged_prefill
                          else None,
                          blocks=blocks, n_shared=len(shared),
                          n_prefilled=len(shared) * bs, seq=self._seq)
        self._seq += 1
        self.slots[i] = slot
        self.tables[i, :] = self.layout.sentinel
        self.tables[i, :len(blocks)] = blocks
        self._note_blocks()
        return True

    def _free_slot(self, i: int, slot: _PagedSlot) -> None:
        for b in slot.blocks:
            self.alloc.release(b)
        slot.blocks = []
        self.tables[i, :] = self.layout.sentinel

    def _commit(self, idx: int, slot: _Slot, tok: int,
                finished: list[RequestOutput]) -> None:
        super()._commit(idx, slot, tok, finished)
        if self.slots[idx] is None:        # retired: block-granular free
            self._free_slot(idx, slot)
            self._note_blocks()

    def _evict_youngest(self, protect: int) -> None:
        """Preempt the youngest other request: free its blocks, requeue it
        at the front (FIFO by admission order is preserved — it was
        admitted before everything still queued). Greedy decoding and the
        seeded ``fold_in`` sampling stream both replay identically on
        recompute, so outputs are unchanged."""
        victims = [(s.seq, j) for j, s in enumerate(self.slots)
                   if s is not None and j != protect]
        if not victims:
            raise RuntimeError(
                "pool exhausted with no evictable request — unreachable "
                "when n_blocks * block_size >= max_len")
        _, j = max(victims)
        slot = self.slots[j]
        self._free_slot(j, slot)
        self.queue.appendleft(slot.req)
        self.slots[j] = None
        self.stats.evictions += 1
        self.tel.on_eviction(slot.req.uid)

    def _ensure_decode_block(self, i: int) -> None:
        """Grow slot ``i``'s table to cover its next write position,
        evicting (youngest-first) under pressure."""
        slot = self.slots[i]
        bi = slot.host_pos // self.layout.block_size
        while len(slot.blocks) <= bi:
            while not self.alloc.free:
                self._evict_youngest(protect=i)
            slot.blocks.extend(self.alloc.alloc(1))
            self.tables[i, len(slot.blocks) - 1] = slot.blocks[-1]
        self._note_blocks()

    def _register_prompt_blocks(self, slot: _PagedSlot) -> None:
        """Publish the finished prompt's full private blocks for sharing."""
        if not self.prefix_sharing:
            return
        prompt = np.asarray(slot.req.prompt, np.int32)
        for j in range(slot.n_shared,
                       prompt.shape[0] // self.layout.block_size):
            self.alloc.register(slot.blocks[j],
                                self.alloc.prefix_key(prompt, j))

    # ------------------------------------------------------------- engine
    def step(self) -> list[RequestOutput]:
        """One iteration: admit (FIFO, queue-until-blocks-free) → prefill
        one chunk per admitted-but-not-live slot → one batched paged
        decode for live slots (lazy block growth, eviction under
        pressure). Host syncs are batched as in the parent engine."""
        finished: list[RequestOutput] = []
        # 1. admission
        with self.tel.span("admission"):
            free_idx = [i for i, s in enumerate(self.slots) if s is None]
            while free_idx and self.queue:
                if not self._try_admit(free_idx[0]):
                    break                   # head waits; FIFO holds
                free_idx.pop(0)
        # 2. prefill: one chunk per mid-prefill slot
        done: list[tuple[int, _PagedSlot, Any]] = []
        for i, slot in enumerate(self.slots):
            if slot is None or slot.live:
                continue
            prompt = np.asarray(slot.req.prompt, np.int32)
            lo, hi = slot.n_prefilled, min(slot.n_prefilled
                                           + self.prefill_chunk,
                                           prompt.shape[0])
            toks = jnp.asarray(prompt[None, lo:hi])
            with self.tel.span("prefill_chunk", uid=slot.req.uid,
                               lo=lo, hi=hi), \
                    self.tel.annotate_step("prefill_chunk",
                                           self.stats.prefill_chunks):
                if self.staged_prefill:
                    with self._on(self.prefill_mesh):
                        logits, slot.state1 = self._chunk(
                            self._params_p, self._plans_p, slot.state1, toks)
                else:
                    with self._on(self.decode_mesh):
                        logits, self.state = self._chunk_paged(
                            self._params_d, self._plans_d, self.state, toks,
                            jnp.asarray(i, jnp.int32),
                            jnp.asarray(self.tables[i]),
                            jnp.asarray(lo, jnp.int32))
            slot.n_prefilled = hi
            self.stats.prefill_chunks += 1
            self.tel.on_prefill_chunk(slot.req.uid, lo, hi)
            if hi == prompt.shape[0]:
                if self.staged_prefill:
                    one = slot.state1
                    if self._rep_d is not None:   # hand blocks to the
                        one = jax.device_put(one, self._rep_d)  # decode slice
                    with self._on(self.decode_mesh):
                        self.state = self._insert(
                            self.state, one, jnp.asarray(i, jnp.int32),
                            jnp.asarray(self.tables[i]))
                    slot.state1 = None
                slot.live = True
                slot.host_pos = hi
                self._register_prompt_blocks(slot)
                done.append((i, slot, logits[0, -1]))
        if done:
            rows = jax.device_get([lg for _, _, lg in done])
            for (i, slot, _), row in zip(done, rows):
                self._commit(i, slot,
                             self._sample(slot, row, int(np.argmax(row))),
                             finished)
        # 3. batched paged decode over live slots
        live = [i for i, s in enumerate(self.slots)
                if s is not None and s.live]
        for i in sorted(live, key=lambda j: self.slots[j].seq):
            if self.slots[i] is not None:   # an eviction may have taken it
                self._ensure_decode_block(i)
        live = [i for i, s in enumerate(self.slots)
                if s is not None and s.live]
        if live:
            toks = np.zeros((self.n_slots, 1), np.int32)
            tables = np.full_like(self.tables, self.layout.sentinel)
            for i in live:
                toks[i, 0] = self.slots[i].next_tok
                tables[i] = self.tables[i]  # non-live rows stay sentinel
            with self.tel.span("decode_step", n_live=len(live)):
                t0 = time.perf_counter()
                with self.tel.annotate_step("decode_step",
                                            self.stats.decode_steps), \
                        self._on(self.decode_mesh):
                    out = self._decode(
                        self._params_d, self._plans_d, self.state,
                        jnp.asarray(toks), jnp.asarray(tables))
                rows, self.state = self._decode_fetch(out, len(live))
                self.tel.observe_decode_step_seconds(
                    time.perf_counter() - t0)
                self.stats.decode_steps += 1
                self.stats.decode_slot_tokens += len(live)
                self.tel.on_decode_step(len(live))
                greedy = np.argmax(rows, axis=-1)
                for i in live:
                    slot = self.slots[i]
                    slot.host_pos += 1
                    self._commit(i, slot,
                                 self._sample(slot, rows[i],
                                              int(greedy[i])), finished)
        return finished
