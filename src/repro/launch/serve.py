"""Serving launcher: batched generation demo.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 4 --steps 16 [--pim fast]

``--pim fast`` routes weight-static projections through the centered int8
path (Eq. 1 on the MXU) — see examples/serve_quantized.py for the
end-to-end accuracy comparison.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = T.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.steps + 1,
                      temperature=args.temperature)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size))
    t0 = time.monotonic()
    res = eng.generate(prompts, steps=args.steps)
    dt = time.monotonic() - t0
    print(f"{cfg.name}: generated {res.tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print(res.tokens[:2])


if __name__ == "__main__":
    main()
