"""Serving launcher: continuous-batching generation demo.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 8 --engine continuous [--pim fast]

``--engine continuous`` (default) drives the slot-based scheduler on a
mixed-length request trace and reports decode-step utilization next to
throughput; ``--engine lockstep`` runs the fixed-batch reference engine;
``--engine paged`` serves the trace from a paged KV block pool
(``--block-size``/``--blocks``, see ``repro.serve.PagedServeEngine``)
and reports pool occupancy, prefix-sharing hits, and evictions.
``--pim fast`` compiles the params with the per-site architecture
compiler (``repro.models.pim_compile``, on a random calibration batch)
and routes every weight-static projection through the centered int8 path
(Eq. 1 on the MXU); ``--pim exact`` runs the bit-exact accelerator
simulation, ``--pim int8`` the ideal 8b-quantized reference — see
``benchmarks/serve_pim.py`` for the throughput comparison.
``--pim-slicing adaptive`` runs the paper's Algorithm 1 per projection
site (printing the slice-count histogram and per-site table);
``--pim-slicing 4,2,2`` pins every site. ``--device-corner 3sigma``
(with ``--pim exact``) serves on a nonideal ReRAM die — the
``repro.core.backends`` device model with program noise, drift,
stuck-at faults, and IR drop at the named corner. See
``benchmarks/compile_report.py`` for the Titanium-Law pricing of the
compiled plan.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs, obs
from repro.models import pim
from repro.models import transformer as T
from repro.serve import (
    ContinuousServeEngine,
    PagedServeEngine,
    Request,
    ServeEngine,
)


def build_trace(n: int, *, prompt_len: int, steps: int, vocab: int,
                seed: int = 1) -> list[Request]:
    """Mixed-length trace: prompt lengths in [prompt_len/2, prompt_len],
    output lengths in [steps/4, steps] — the raggedness a lockstep batch
    pads away."""
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(max(1, steps // 4), steps + 1))))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("continuous", "lockstep", "paged"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8,
                    help="trace length (continuous) / batch size (lockstep)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: KV tokens per pool block")
    ap.add_argument("--blocks", type=int, default=None,
                    help="paged engine: pool size in blocks (default "
                         "slots * max_len/block_size — no memory pressure; "
                         "smaller values exercise queue-until-blocks-free "
                         "and eviction-by-recompute)")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot here as JSON "
                         "(Prometheus text exposition included; "
                         "repro.obs.export.write_metrics)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace (Perfetto-loadable) span "
                         "log of the run here")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the run in jax.profiler start/stop_trace "
                         "writing the device profile here")
    ap.add_argument("--pim", choices=("off", "fast", "exact", "int8"),
                    default="off")
    ap.add_argument("--pim-slicing", default=None,
                    help="'adaptive' (Algorithm 1 per projection site) or "
                         "a comma tuple like '4,2,2' pinning every site")
    ap.add_argument("--device-corner", default=None,
                    choices=("nominal", "1sigma", "3sigma"),
                    help="run --pim exact on a nonideal ReRAM die "
                         "(repro.core.backends.NonidealSim): conductance "
                         "program noise, retention drift, stuck-at fault "
                         "maps, IR drop at the named corner. 'nominal' is "
                         "the all-zero corner (bit-exact with the ideal "
                         "sim — the zero-corner contract)")
    ap.add_argument("--device-seed", type=int, default=0,
                    help="die seed for --device-corner fault/noise maps")
    ap.add_argument("--kernel-backend", default=None,
                    choices=("auto", "xla", "interpret", "pallas",
                             "pallas-tpu", "pallas-gpu", "python"),
                    help="repro.kernels.ops registry backend for the PIM "
                         "kernels (fused exact datapath / fast matmul); "
                         "'auto' = pallas-tpu on TPU, XLA ref elsewhere. "
                         "REPRO_KERNEL_BACKEND overrides at dispatch time")
    args = ap.parse_args()

    if args.engine == "lockstep" and (args.metrics_out or args.trace_out
                                      or args.profile_dir):
        ap.error("--metrics-out/--trace-out/--profile-dir instrument the "
                 "continuous/paged scheduler loops; the lockstep "
                 "reference engine has no request lifecycle to trace")
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.pim != cfg.pim_mode:
        cfg = dataclasses.replace(cfg, pim_mode=args.pim)
    if args.kernel_backend is not None:
        if cfg.pim_mode == "off":
            ap.error("--kernel-backend requires --pim fast|exact|int8")
        cfg = dataclasses.replace(cfg, pim_kernel_backend=args.kernel_backend)
    if args.device_corner is not None:
        if cfg.pim_mode != "exact":
            ap.error("--device-corner requires --pim exact (only the "
                     "bit-exact accelerator simulation models the analog "
                     "array)")
        cfg = dataclasses.replace(cfg, pim_crossbar_backend="nonideal",
                                  pim_device_corner=args.device_corner,
                                  pim_device_seed=args.device_seed)
        print(f"device corner: {args.device_corner} "
              f"(die seed {args.device_seed}, nonideal ReRAM array)")
    if args.pim_slicing is not None:
        if cfg.pim_mode == "off":
            ap.error("--pim-slicing requires --pim fast|exact|int8 "
                     "(the float path has no compile step)")
        slicing = args.pim_slicing if args.pim_slicing == "adaptive" \
            else tuple(int(b) for b in args.pim_slicing.split(","))
        cfg = dataclasses.replace(cfg, pim_weight_slicing=slicing)
    params, _ = T.init_params(cfg, jax.random.key(0))
    max_len = args.prompt_len + args.steps + 1

    plans = None
    if cfg.pim_mode != "off":
        calib = np.asarray(jax.random.randint(
            jax.random.key(7), (2, max(args.prompt_len, 4)), 0,
            cfg.vocab_size))
        t0 = time.monotonic()
        compiled = pim.compile_pim_params(params, cfg, calib)
        plans = compiled.plans
        print(f"compiled pim plans ({cfg.pim_mode}, "
              f"slicing={cfg.pim_weight_slicing}) in "
              f"{time.monotonic() - t0:.2f}s: {len(compiled.sites)} sites, "
              f"slice histogram {compiled.slice_histogram()}")
        if cfg.pim_weight_slicing == "adaptive":
            for sp in compiled.sites:
                err = "-" if sp.error is None else f"{sp.error:.4f}"
                print(f"  {sp.site:36s} {'-'.join(map(str, sp.slicing)):16s}"
                      f" err={err}")

    if args.engine == "lockstep":
        eng = ServeEngine(cfg, params, max_len=max_len,
                          temperature=args.temperature, plans=plans)
        prompts = np.asarray(jax.random.randint(
            jax.random.key(1), (args.requests, args.prompt_len), 0,
            cfg.vocab_size))
        t0 = time.monotonic()
        res = eng.generate(prompts, steps=args.steps)
        dt = time.monotonic() - t0
        print(f"{cfg.name} lockstep: generated {res.tokens.shape} in "
              f"{dt:.2f}s ({args.requests * args.steps / dt:.1f} tok/s)")
        print(res.tokens[:2])
        return

    tel = obs.ServeTelemetry(engine=args.engine,
                             tracing=args.trace_out is not None,
                             profile_dir=args.profile_dir)
    trace = build_trace(args.requests, prompt_len=args.prompt_len,
                        steps=args.steps, vocab=cfg.vocab_size)
    for i, r in enumerate(trace):
        trace[i] = dataclasses.replace(r, temperature=args.temperature)
    if args.engine == "paged":
        max_len = -(-max_len // args.block_size) * args.block_size
        eng = PagedServeEngine(cfg, params, n_slots=args.slots,
                               max_len=max_len,
                               prefill_chunk=args.prefill_chunk,
                               block_size=args.block_size,
                               n_blocks=args.blocks, plans=plans,
                               telemetry=tel)
    else:
        eng = ContinuousServeEngine(cfg, params, n_slots=args.slots,
                                    max_len=max_len,
                                    prefill_chunk=args.prefill_chunk,
                                    plans=plans, telemetry=tel)
    t0 = time.monotonic()
    with tel.profile():
        outs = eng.run(trace)
    dt = time.monotonic() - t0
    total = sum(len(o.tokens) for o in outs)
    st = eng.stats
    print(f"{cfg.name} {args.engine}: {len(outs)} requests, {total} tokens in "
          f"{dt:.2f}s ({total / dt:.1f} tok/s)")
    print(f"decode utilization {st.decode_utilization:.2f} tokens/step over "
          f"{args.slots} slots ({st.decode_steps} decode steps, "
          f"{st.prefill_chunks} prefill chunks)")
    if args.engine == "paged":
        print(f"block pool: peak {st.peak_blocks_in_use}/"
              f"{eng.alloc.n_blocks} blocks of {args.block_size}, "
              f"{st.prefix_block_hits} prefix hits, {st.evictions} "
              f"evictions, {st.admission_waits} admission waits")
    tel.record_stats(st)
    if args.metrics_out:
        obs.write_metrics(tel.registry, args.metrics_out,
                          config={"arch": cfg.name, "engine": args.engine,
                                  "pim_mode": cfg.pim_mode,
                                  "requests": args.requests,
                                  "slots": args.slots},
                          stats=st.snapshot())
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        tel.tracer.write(args.trace_out)
        print(f"chrome trace ({len(tel.tracer.events())} events) -> "
              f"{args.trace_out}")
    if args.profile_dir:
        print(f"jax profiler trace -> {args.profile_dir}")
    print("first outputs:", {o.uid: o.tokens[:8].tolist() for o in outs[:2]})


if __name__ == "__main__":
    main()
