"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 100 \
      --reduced --ckpt-dir /tmp/ckpt

``--reduced`` runs the smoke-scale config (CPU-friendly); without it the
full config is built (requires a real TPU slice — on this container use the
dry-run instead). Fault tolerance: --resilient wraps the loop with
checkpoint/restart + straggler monitoring.
"""

from __future__ import annotations

import argparse


from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt
from repro.train import train_loop as tl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resilient", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = opt.AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps,
                              state_dtype=cfg.opt_state_dtype)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=0)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, loss floor ~{data.entropy_floor():.3f}")
    if args.resilient:
        if not args.ckpt_dir:
            raise SystemExit("--resilient requires --ckpt-dir")
        state = ft.resilient_train(
            cfg, opt_cfg, lambda s: data.iterator(s),
            num_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every)
    else:
        hooks = [ft.StragglerMonitor().hook()]
        if args.ckpt_dir:
            from repro.train import checkpoint as ckpt
            saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
            hooks.append(lambda st, m, dt: (
                saver.save(st.step, (st.params, st.opt_state))
                if st.step % args.ckpt_every == 0 else None))
        state = tl.train(cfg, opt_cfg, data.iterator(0),
                         num_steps=args.steps, hooks=hooks)
    print(f"done at step {state.step}")


if __name__ == "__main__":
    main()
