"""Loop-aware roofline extraction from compiled SPMD HLO.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE — for a
scanned transformer that under-counts FLOPs, bytes and collectives by the
layer count (and again by microbatch/chunk scan trips). This module parses
the post-optimization HLO text into computations, recovers each while
loop's trip count from its condition, and aggregates:

  - dot FLOPs (2 x result x contracting size), loop-scaled
  - approximate HBM bytes (operand + result bytes of materializing ops),
    loop-scaled
  - collective link bytes per kind (ring-model factors), loop-scaled

Shapes in post-SPMD HLO are per-device, so all totals are per-device.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s+\((.*)\)\s*->.*\{\s*$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*([a-z0-9]+\[[\d,]*\])")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([a-z][a-z0-9\-]*)\(")
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
# ops that certainly touch HBM on TPU: fusion boundaries, MXU ops,
# collectives, gathers/scatters, big copies. Elementwise converts /
# broadcasts / transposes / pads fuse into neighbours on TPU and are
# excluded (the CPU backend materializes them, which is not representative).
# loop-state copies are aliased in-place by XLA on TPU; DUS/DS of loop
# state touch only the updated/read window, not the whole operand.
_MATERIALIZING = {"fusion", "dot", "concatenate", "scatter",
                  "gather", "reduce", "select-and-scatter", "sort", "rng",
                  "convolution"} | set(COLLECTIVE_OPS)
_WINDOW_OPS = {"dynamic-update-slice", "dynamic-slice"}


def _shape_info(type_str: str) -> tuple[int, list[tuple[str, list[int]]]]:
    """Bytes + [(dtype, dims)] for every array shape in a type string."""
    total, shapes = 0, []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        shapes.append((dtype, dl))
    return total, shapes


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_str: str
    line: str
    result_bytes: int
    shapes: list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    param_shapes: dict       # name -> (bytes, shapes)
    name2instr: dict


def parse_computations(txt: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr:
            params = {}
            for pname, ptype in _PARAM_RE.findall(hdr.group(2)):
                params[pname.lstrip("%")] = _shape_info(ptype)
            cur = Computation(hdr.group(1), [], params, {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        rb, shapes = _shape_info(type_str)
        ins = Instr(name, op, type_str, line, rb, shapes)
        cur.instrs.append(ins)
        cur.name2instr[name] = ins
    return comps


def _operand_names(line: str) -> list[str]:
    # find the argument list of the op call: first '(...)' after the op name
    call = re.search(r"[a-z][a-z0-9\-]*\(([^)]*)\)", line)
    if not call:
        return []
    args = call.group(1)
    # operands are "%name" tokens; typed forms ("f32[64,128]{1,0} %name")
    # contain commas inside the shape, so splitting the list on "," breaks
    names = re.findall(r"%([\w.\-]+)", args)
    if names:
        return names
    return [a.strip().split(" ")[-1] for a in args.split(",") if a.strip()]


def _operand_bytes(comp: Computation, line: str) -> int:
    total = 0
    for nm in _operand_names(line):
        if nm in comp.name2instr:
            total += comp.name2instr[nm].result_bytes
        elif nm in comp.param_shapes:
            total += comp.param_shapes[nm][0]
    return total


def _dot_flops(comp: Computation, ins: Instr) -> float:
    """2 x prod(result dims) x prod(lhs contracting dims)."""
    ops = _operand_names(ins.line)
    lhs_shapes = None
    if ops:
        nm = ops[0]
        if nm in comp.name2instr:
            lhs_shapes = comp.name2instr[nm].shapes
        elif nm in comp.param_shapes:
            lhs_shapes = comp.param_shapes[nm][1]
    m = _CONTRACT.search(ins.line)
    k = 1
    if m and lhs_shapes:
        dims = lhs_shapes[0][1]
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(dims):
                k *= dims[idx]
    result_elems = 0
    for dtype, dl in ins.shapes:
        n = 1
        for d in dl:
            n *= d
        result_elems += n
    return 2.0 * result_elems * k


def _trip_count(comps: dict, while_line: str, cond_name: str) -> int:
    """Trip count: XLA's known_trip_count if present, else the condition's
    comparison constant."""
    m = re.search(r'known_trip_count[^0-9]*(\d+)', while_line)
    if m:
        return int(m.group(1))
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for ins in cond.instrs:
        mm = re.search(r"constant\((\d+)\)", ins.line)
        if mm:
            consts.append(int(mm.group(1)))
    return max(consts) if consts else 1


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _collective_link_bytes(ins: Instr) -> tuple[str, float]:
    kind = next(k for k in COLLECTIVE_OPS if ins.op.startswith(k))
    if ins.op.endswith("-done"):
        return kind, 0.0
    payload = ins.result_bytes
    s = _group_size(ins.line)
    if kind == "all-reduce":
        link = 2 * payload * (s - 1) / s
    elif kind == "all-gather":
        link = payload * (s - 1) / s
    elif kind == "reduce-scatter":
        link = payload * (s - 1)
    elif kind == "all-to-all":
        link = payload * (s - 1) / s
    else:
        link = payload
    return kind, link


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            self.flops * k, self.hbm_bytes * k,
            {a: b * k for a, b in self.coll_bytes.items()},
            {a: b * k for a, b in self.coll_counts.items()})

    def add(self, other: "HloCosts") -> None:
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _comp_costs(comps: dict, name: str, memo: dict) -> HloCosts:
    if name in memo:
        return memo[name]
    memo[name] = HloCosts()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    total = HloCosts()
    for ins in comp.instrs:
        if ins.op == "while":
            b = _BODY.search(ins.line)
            c = _COND.search(ins.line)
            trips = _trip_count(comps, ins.line, c.group(1) if c else "")
            if b:
                body = _comp_costs(comps, b.group(1), memo)
                total.add(body.scaled(max(trips, 1)))
            if c:
                total.add(_comp_costs(comps, c.group(1), memo))
            continue
        if ins.op in ("fusion", "call", "custom-call", "conditional",
                      "map", "reduce", "reduce-window", "sort", "scatter",
                      "select-and-scatter", "async-start"):
            for sub in _CALLS.findall(ins.line):
                total.add(_comp_costs(comps, sub, memo))
        if ins.op == "dot" or ins.op == "convolution":
            total.flops += _dot_flops(comp, ins)
        if any(ins.op.startswith(k) for k in COLLECTIVE_OPS):
            kind, link = _collective_link_bytes(ins)
            if link > 0:
                total.coll_bytes[kind] = total.coll_bytes.get(kind, 0.) + link
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
        if ins.op in _WINDOW_OPS:
            if ins.op == "dynamic-slice":
                total.hbm_bytes += 2 * ins.result_bytes
            else:  # dynamic-update-slice: read+write the update window
                ops_ = _operand_names(ins.line)
                upd = 0
                if len(ops_) >= 2:
                    nm = ops_[1]
                    if nm in comp.name2instr:
                        upd = comp.name2instr[nm].result_bytes
                    elif nm in comp.param_shapes:
                        upd = comp.param_shapes[nm][0]
                total.hbm_bytes += 2 * upd
        elif ins.op in _MATERIALIZING:
            total.hbm_bytes += ins.result_bytes + _operand_bytes(comp,
                                                                 ins.line)
    memo[name] = total
    return total


def analyze(hlo_text: str, entry: str | None = None) -> HloCosts:
    """Loop-aware per-device costs for the entry computation."""
    comps = parse_computations(hlo_text)
    if entry is None:
        m = re.search(r"^ENTRY %?([\w.\-]+)", hlo_text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict = {}
    # fusions' internal dots must not double count their parents' operand
    # bytes; acceptable approximation at roofline granularity.
    return _comp_costs(comps, entry, memo)


# ------------------------------------------------- legacy simple interface
@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Loop-aware collective stats (kept for API compatibility)."""
    c = analyze(hlo_text)
    return CollectiveStats(c.coll_bytes, c.coll_counts)


# TPU v5e hardware constants (the roofline denominators)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops: float, hbm_bytes: float,
                   coll_bytes: float) -> dict:
    """All inputs per-device. Returns the three terms in seconds."""
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_collective = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(t_compute, t_memory, t_collective)
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms
