import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The CPU backend emulates bf16 via f32 converts; loop-invariant code motion
# then hoists the convert of whole saved-residual stacks out of the backward
# while-loop, materializing f32 copies of every layer at once. TPU has
# native bf16 — suppress the artifact so per-device numbers are meaningful.
os.environ["XLA_FLAGS"] += " --xla_disable_hlo_passes=while-loop-invariant-code-motion"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell with the production shardings, and extract the roofline terms
from the compiled artifact.

No arrays are ever allocated: params/optimizer/caches are ShapeDtypeStructs
(jax.eval_shape) and the jit is only lowered and compiled. A cell passing
here proves the distribution config is coherent — shardings consistent,
collectives legal, per-device memory within HBM.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun
  python -m repro.launch.dryrun --arch jamba-1.5-large-398b --shape long_500k \
      --single-pod-only
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ArchConfig, InputShape
from repro.dist import sharding as dsh
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.train import optimizer as opt

HBM_PER_CHIP = 16 * 1024 ** 3  # TPU v5e


# ------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            inputs = jax.ShapeDtypeStruct((B, S), tok)
        else:
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        return {"inputs": inputs, "labels": jax.ShapeDtypeStruct((B, S), tok)}
    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            return {"inputs": jax.ShapeDtypeStruct((B, S), tok)}
        return {"inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)}
    # decode: one new token against a seq_len cache
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}


def batch_logical(cfg: ArchConfig, shape: InputShape) -> dict:
    if shape.kind == "train":
        il = ("batch", "seq") if cfg.input_mode == "tokens" \
            else ("batch", "seq", None)
        return {"inputs": il, "labels": ("batch", "seq")}
    if shape.kind == "prefill":
        il = ("batch", "seq") if cfg.input_mode == "tokens" \
            else ("batch", "seq", None)
        return {"inputs": il}
    tl = ("batch", None) if cfg.input_mode == "tokens" \
        else ("batch", None, None)
    return {"tokens": tl}


def _shardings(spec_tree, mesh, abstract_tree=None):
    sh = jax.tree.map(
        lambda ax: NamedSharding(mesh, dsh.spec_for(ax, mesh)),
        spec_tree, is_leaf=lambda x: isinstance(x, tuple))
    if abstract_tree is not None:
        sh = dsh.sanitize_shardings(sh, abstract_tree)
    return sh


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


# ------------------------------------------------------------- step builders
from repro.train.train_loop import make_train_step  # noqa: E402  (shared with
# the real launcher: the dry-run lowers exactly what training runs)


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch["inputs"])
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, batch):
        return T.decode_step(params, cfg, state, batch["tokens"])
    return serve_step


# ------------------------------------------------------------- cell dry-run
def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = {s.name: s for s in configs.runnable_shapes(cfg)}.get(shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "assignment skip rule (see DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "decode":
        rules = dsh.MULTIPOD_SERVE_RULES if multi_pod else dsh.SERVE_RULES
    else:
        rules = dsh.MULTIPOD_RULES if multi_pod else dsh.DEFAULT_RULES
    t0 = time.time()
    with dsh.axis_rules(rules):
        pspecs = T.param_specs(cfg)
        params_abs = _abstract(lambda: T.init_params(cfg, jax.random.key(0))[0])
        params_sh = _shardings(pspecs, mesh, params_abs)
        batch_abs = input_specs(cfg, shape)
        batch_sh = _shardings(batch_logical(cfg, shape), mesh, batch_abs)
        repl = NamedSharding(mesh, P())

        if shape.kind == "train":
            opt_cfg = opt.AdamWConfig(state_dtype=cfg.opt_state_dtype)
            opt_abs = _abstract(lambda: opt.init_state(opt_cfg, params_abs))
            opt_sh = _shardings(opt.state_specs(pspecs), mesh, opt_abs)
            step = make_train_step(cfg, opt_cfg)
            metrics_sh = {"grad_norm": repl, "lr": repl, "loss": repl}
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, metrics_sh),
                             donate_argnums=(0, 1))
            args = (params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            logits_abs, cache_abs = _abstract(step, params_abs, batch_abs)
            cache_sh = _shardings(T.cache_specs(cfg), mesh, cache_abs)
            logits_sh = dsh.sanitize_shardings(
                NamedSharding(mesh, dsh.spec_for(("batch", None, "vocab"),
                                                 mesh)), logits_abs)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=(logits_sh, cache_sh))
            args = (params_abs, batch_abs)
        else:  # decode
            step = make_serve_step(cfg)
            state_abs = _abstract(
                lambda: T.init_decode_state(cfg, shape.global_batch,
                                            shape.seq_len))
            cache_sh = _shardings(T.cache_specs(cfg), mesh, state_abs)
            logits_abs, _ = _abstract(step, params_abs, state_abs, batch_abs)
            logits_sh = dsh.sanitize_shardings(
                NamedSharding(mesh, dsh.spec_for(("batch", None, "vocab"),
                                                 mesh)), logits_abs)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, cache_sh, batch_sh),
                             out_shardings=(logits_sh, cache_sh),
                             donate_argnums=(1,))
            args = (params_abs, state_abs, batch_abs)

        with mesh:  # in-model logical sharding constraints bind to this mesh
            lowered = jitted.lower(*args)
            compiled = lowered.compile()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    # loop-aware analysis: XLA's cost_analysis visits while bodies once,
    # under-counting scanned layers by the trip count — parse the HLO and
    # scale loop bodies ourselves (repro.launch.hlo_analysis).
    costs = hlo.analyze(compiled.as_text())
    flops = costs.flops
    bytes_acc = costs.hbm_bytes
    terms = hlo.roofline_terms(flops, bytes_acc, costs.total_coll_bytes)

    n_chips = int(np.prod(list(mesh.shape.values())))
    dev_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    # embedding lookups are gathers, not MACs: exclude the table from the
    # useful-FLOPs numerator (the LM head IS a matmul and stays counted)
    n_flops_params = n_active - cfg.vocab_size * cfg.d_model
    model_flops = (6 if shape.kind == "train" else 2) * n_flops_params * tokens
    model_flops_per_chip = model_flops / n_chips

    report = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "n_chips": n_chips, "status": "ok",
        "per_device_bytes": int(dev_bytes),
        "per_device_gib": round(dev_bytes / 1024 ** 3, 3),
        "fits_hbm": bool(dev_bytes <= HBM_PER_CHIP),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": costs.total_coll_bytes,
        "collective_breakdown": costs.coll_bytes,
        "collective_counts": costs.coll_counts,
        "xla_cost_analysis_flops_loop_once": float(ca.get("flops", 0.0)),
        "model_flops_per_device": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "params_total": cfg.param_count(),
        "params_active": n_active,
        "compile_seconds": round(time.time() - t0, 1),
        **terms,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'}] "
              f"{report['per_device_gib']} GiB/dev "
              f"fits={report['fits_hbm']} "
              f"compute={terms['compute_s']:.3e}s "
              f"mem={terms['memory_s']:.3e}s "
              f"coll={terms['collective_s']:.3e}s "
              f"bound={terms['bottleneck']} "
              f"({report['compile_seconds']}s compile)")
        print("  memory_analysis:", ma)
        cak = {k: ca[k] for k in ("flops", "bytes accessed") if k in ca}
        print("  cost_analysis:", cak)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON reports")
    args = ap.parse_args()

    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]

    cells = []
    archs = configs.ASSIGNED if (args.all or not args.arch) else (args.arch,)
    for a in archs:
        cfg = configs.get(a)
        shapes = [s.name for s in configs.runnable_shapes(cfg)]
        if args.shape:
            shapes = [args.shape] if args.shape in shapes else []
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    results, failures = [], 0
    for a, s, mp in cells:
        try:
            r = dryrun_cell(a, s, multi_pod=mp)
        except Exception as e:  # a failing cell is a bug in the system
            traceback.print_exc()
            r = {"arch": a, "shape": s, "multi_pod": mp, "status": "FAILED",
                 "error": f"{type(e).__name__}: {e}"}
            failures += 1
        results.append(r)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"{a}_{s}_{'mp' if mp else 'sp'}.json"
            with open(os.path.join(args.out, tag), "w") as f:
                json.dump(r, f, indent=2)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\ndry-run: {ok} ok / {failures} failed / "
          f"{len(results) - ok - failures} skipped, {len(results)} cells")
    if args.out:
        with open(os.path.join(args.out, "summary.json"), "w") as f:
            json.dump(results, f, indent=2)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
