"""Production mesh construction (TPU v5e pods).

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA flags first).
"""

from __future__ import annotations

import jax

import repro.dist  # noqa: F401  (installs jax API compat shims: AxisType,
#                                 make_mesh(axis_types=...) on jax < 0.5)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) devices tests have."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
