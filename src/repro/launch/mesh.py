"""Production mesh construction (TPU v5e pods).

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA flags first).
"""

from __future__ import annotations

import jax

import repro.dist  # noqa: F401  (installs jax API compat shims: AxisType,
#                                 make_mesh(axis_types=...) on jax < 0.5)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_disaggregated_meshes(
        prefill: tuple[int, int, int] = (1, 2, 2),
        decode: tuple[int, int, int] = (1, 2, 2),
) -> tuple[jax.sharding.Mesh, jax.sharding.Mesh]:
    """Split the device fleet into a prefill slice and a decode slice.

    Both meshes use the ``("pod", "data", "model")`` axis names so the
    ``MULTIPOD_SERVE`` rule set (``repro.dist``) applies verbatim on
    either slice — the KV ``cache_batch`` axis shards over
    ``("pod", "data")`` and weights over ``"model"`` exactly as on a
    single multi-pod mesh. The prefill slice takes the first
    ``prod(prefill)`` devices, the decode slice the next
    ``prod(decode)``; ``repro.serve.PagedServeEngine`` replicates params
    and compiled PIM plans to both and hands finished prefill blocks to
    the decode slice.
    """
    import numpy as np

    need_p = int(np.prod(prefill))
    need_d = int(np.prod(decode))
    devs = jax.devices()
    if len(devs) < need_p + need_d:
        raise ValueError(
            f"disaggregated serving needs {need_p}+{need_d} devices, "
            f"have {len(devs)}")
    axes = ("pod", "data", "model")
    mk = jax.sharding.Mesh
    return (mk(np.asarray(devs[:need_p]).reshape(prefill), axes),
            mk(np.asarray(devs[need_p:need_p + need_d]).reshape(decode),
               axes))


def make_test_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) devices tests have."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
