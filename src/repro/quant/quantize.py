"""8b per-channel linear quantization (paper §2.1, [82]-style).

The paper's supported scheme: 8b inputs/weights, 16b psums, per-output-channel
weight scales, outputs digitally requantized back to 8b with an FP16
scale+bias (activation functions folded into requantization).

Weight convention on-crossbar: unsigned 8b domain w_u = w_q + 128 (the +128
folds into the digital center term — see core.center_offset). Inputs are
unsigned 8b for ReLU-family activations; signed inputs are processed as two
unsigned passes max(x,0) / max(-x,0) per the paper.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    values: jnp.ndarray            # int8 / uint8-domain int32
    scale: jnp.ndarray             # per-channel or scalar fp32
    zero_point: jnp.ndarray        # same shape as scale, int32
    signed: bool


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """All quantization parameters of one linear layer y = x @ w + b."""
    w_scale: jnp.ndarray           # (cols,) fp32 — per-output-channel
    x_scale: jnp.ndarray           # scalar fp32
    x_zero_point: jnp.ndarray      # scalar int32 (0 when inputs signed)
    x_signed: bool
    out_scale: jnp.ndarray         # scalar fp32 — 8b output requant scale
    out_zero_point: jnp.ndarray    # scalar int32
    bias: jnp.ndarray | None       # (cols,) fp32 or None


def quantize_weights_per_channel(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """w (rows, cols) fp -> (w_q int8 symmetric per-col, scale (cols,))."""
    absmax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)


def quantize_weights_centered(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Center+Offset quantization in the float domain (paper Eq. 1 on TPU).

    Per output channel: center = midpoint of [min, max], scale = half-range /
    127. Offsets are guaranteed int8. For channels with skewed weight
    distributions this gives up to 2x finer resolution than symmetric int8 —
    the TPU-native payoff of the paper's centering insight.

    w (rows, cols) fp -> (w_off int8, centers int32 (cols,), scale (cols,)).
    Reconstruction: w ~= scale * (w_off + centers).
    """
    w_min = jnp.min(w, axis=0)
    w_max = jnp.max(w, axis=0)
    mid = 0.5 * (w_max + w_min)
    half = jnp.maximum(0.5 * (w_max - w_min), 1e-12)
    scale = half / 127.0
    centers = jnp.round(mid / scale).astype(jnp.int32)
    w_off = jnp.clip(jnp.round(w / scale) - centers, -127, 127).astype(jnp.int8)
    return w_off, centers, scale.astype(jnp.float32)


def quantize_inputs_unsigned(
        x: jnp.ndarray,
        x_max: jnp.ndarray | float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ReLU-family activations: x in [0, x_max] -> uint8 [0, 255]."""
    scale = jnp.maximum(jnp.asarray(x_max, jnp.float32), 1e-12) / 255.0
    x_q = jnp.clip(jnp.round(x / scale), 0, 255).astype(jnp.int32)
    return x_q, scale


def quantize_inputs_signed(
        x: jnp.ndarray,
        x_absmax: jnp.ndarray | float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Signed activations -> int8 [-127, 127] symmetric."""
    scale = jnp.maximum(jnp.asarray(x_absmax, jnp.float32), 1e-12) / 127.0
    x_q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    return x_q, scale


def dequantize(y_int: jnp.ndarray, lq: LayerQuant,
               x_q_sum: jnp.ndarray, w_col_sum: jnp.ndarray) -> jnp.ndarray:
    """int32 accumulator (x_q @ w_q algebra) -> float psum.

    y_int is x_q @ w_q where x_q may carry a zero point:
      y = s_w * s_x * (y_int - zp_x * w_col_sum)
    w_col_sum: (cols,) sum of int8 weights per column. x_q_sum kept for
    symmetric-input case (unused; here for API symmetry with PIM path).
    """
    del x_q_sum
    corrected = y_int.astype(jnp.float32) \
        - lq.x_zero_point.astype(jnp.float32) * w_col_sum.astype(jnp.float32)
    y = lq.w_scale[None, :] * lq.x_scale * corrected
    if lq.bias is not None:
        y = y + lq.bias[None, :]
    return y


def requantize_outputs(y: jnp.ndarray, lq: LayerQuant,
                       relu: bool = False) -> jnp.ndarray:
    """float psum -> 8b output codes (activation folded in, paper [82])."""
    if relu:
        y = jnp.maximum(y, 0.0)
    q = jnp.round(y / lq.out_scale) + lq.out_zero_point
    lo, hi = (0, 255) if relu else (-128, 127)
    return jnp.clip(q, lo, hi).astype(jnp.int32)


def calibrate_layer(w: jnp.ndarray, x_cal: jnp.ndarray, *,
                    signed_inputs: bool | None = None,
                    bias: jnp.ndarray | None = None,
                    relu_out: bool = False) -> tuple[LayerQuant, jnp.ndarray]:
    """Build LayerQuant from float weights + calibration activations.

    Returns (LayerQuant, w_q int8). Output scale calibrated from the float
    reference output range on the calibration batch.
    """
    w_q, w_scale = quantize_weights_per_channel(w)
    if signed_inputs is None:
        signed_inputs = bool(jnp.any(x_cal < 0))
    if signed_inputs:
        x_scale = jnp.max(jnp.abs(x_cal)) / 127.0
        zp = jnp.asarray(0, jnp.int32)
    else:
        x_scale = jnp.max(x_cal) / 255.0
        zp = jnp.asarray(0, jnp.int32)
    x_scale = jnp.maximum(x_scale, 1e-12).astype(jnp.float32)
    y_ref = x_cal @ w + (bias if bias is not None else 0.0)
    if relu_out:
        y_ref = jnp.maximum(y_ref, 0.0)
        out_scale = jnp.maximum(jnp.max(y_ref), 1e-12) / 255.0
    else:
        out_scale = jnp.maximum(jnp.max(jnp.abs(y_ref)), 1e-12) / 127.0
    lq = LayerQuant(
        w_scale=w_scale, x_scale=x_scale, x_zero_point=zp,
        x_signed=bool(signed_inputs),
        out_scale=out_scale.astype(jnp.float32),
        out_zero_point=jnp.asarray(0, jnp.int32), bias=bias)
    return lq, w_q
