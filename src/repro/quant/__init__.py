from repro.quant.quantize import (
    LayerQuant,
    QuantizedTensor,
    dequantize,
    quantize_inputs_signed,
    quantize_inputs_unsigned,
    quantize_weights_centered,
    quantize_weights_per_channel,
    requantize_outputs,
)

__all__ = [
    "LayerQuant",
    "QuantizedTensor",
    "dequantize",
    "quantize_inputs_signed",
    "quantize_inputs_unsigned",
    "quantize_weights_centered",
    "quantize_weights_per_channel",
    "requantize_outputs",
]
