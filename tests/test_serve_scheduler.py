"""Continuous-batching scheduler: bit-identity vs the lockstep reference,
chunked prefill, slot lifecycle, admission under a full cache, RNG
guarantees."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import ContinuousServeEngine, Request, ServeEngine

_CACHE: dict = {}


def setup(arch: str):
    if arch not in _CACHE:
        cfg = configs.get(arch).reduced()
        params, _ = T.init_params(cfg, jax.random.key(0))
        _CACHE[arch] = (cfg, params)
    return _CACHE[arch]


def mixed_requests(cfg, n=5, seed=0, **overrides):
    rng = np.random.default_rng(seed)
    plens = [3, 7, 5, 9, 4, 6, 8][:n]
    steps = [6, 3, 9, 4, 7, 2, 5][:n]
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plens[i]).astype(np.int32),
                    max_new_tokens=steps[i], **overrides)
            for i in range(n)]


# ----------------------------------------------------------- model layer
@pytest.mark.parametrize("arch", ["yi-6b", "jamba-1.5-large-398b",
                                  "rwkv6-3b"])
def test_prefill_chunk_matches_prefill(arch):
    """init_decode_state + prefill_chunk* == prefill, bit-for-bit, for
    attention, mamba and rwkv block stacks (recurrent carries continue)."""
    cfg, params = setup(arch)
    prompt = np.asarray(jax.random.randint(
        jax.random.key(1), (1, 9), 0, cfg.vocab_size), np.int32)
    logits_ref, st_ref = T.prefill(params, cfg, jnp.asarray(prompt),
                                   max_len=24)
    st = T.init_decode_state(cfg, 1, 24)
    for lo, hi in [(0, 4), (4, 8), (8, 9)]:
        logits, st = T.prefill_chunk(params, cfg, st,
                                     jnp.asarray(prompt[:, lo:hi]))
    assert jnp.array_equal(logits_ref, logits)
    assert int(st["pos"]) == int(st_ref["pos"]) == 9
    tok = jnp.argmax(logits_ref[:, -1], -1)[:, None].astype(jnp.int32)
    l_ref, _ = T.decode_step(params, cfg, st_ref, tok)
    l_chk, _ = T.decode_step(params, cfg, st, tok)
    assert jnp.array_equal(l_ref, l_chk)


def test_insert_request_and_per_slot_decode():
    """Two B=1 states spliced into a per-slot-pos batched state decode to
    the same logits as each state decoding alone at its own position."""
    cfg, params = setup("yi-6b")
    prompts = [np.arange(1, 6, dtype=np.int32)[None],
               np.arange(2, 10, dtype=np.int32)[None]]
    ones, toks, refs = [], [], []
    for p in prompts:
        logits, st = T.prefill(params, cfg, jnp.asarray(p), max_len=16)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        refs.append(T.decode_step(params, cfg, st, tok)[0])
        ones.append(st)
        toks.append(tok)
    batched = T.init_decode_state(cfg, 2, 16, per_slot_pos=True)
    assert batched["pos"].shape == (2,)
    for i, one in enumerate(ones):
        batched = T.insert_request(batched, one, jnp.asarray(i, jnp.int32))
    assert batched["pos"].tolist() == [5, 8]
    logits, new_state = T.decode_step(params, cfg, batched,
                                      jnp.concatenate(toks, axis=0))
    for i in range(2):
        assert jnp.array_equal(logits[i:i + 1], refs[i])
    assert new_state["pos"].tolist() == [6, 9]


# ---------------------------------------------------------------- engine
@pytest.mark.parametrize("arch", ["yi-6b", "phi3.5-moe-42b"])
def test_continuous_matches_per_request_lockstep(arch):
    """Greedy continuous-batching outputs are bit-identical to running
    each request alone through the lockstep engine (MoE included: the
    merged decode dispatch group is exact when nothing hits capacity)."""
    cfg, params = setup(arch)
    reqs = mixed_requests(cfg)
    eng = ContinuousServeEngine(cfg, params, n_slots=2, max_len=32,
                                prefill_chunk=4)
    outs = eng.run(reqs)
    assert [o.uid for o in outs] == [r.uid for r in reqs]
    ref_eng = ServeEngine(cfg, params, max_len=32)
    for r, o in zip(reqs, outs):
        ref = ref_eng.generate(r.prompt[None, :], steps=r.max_new_tokens)
        assert np.array_equal(o.tokens, ref.tokens[0]), f"uid {r.uid}"
        assert o.finish_reason == "length"
    # 5 requests over 2 slots: slots must have been reused after retirement
    assert eng.stats.completed == 5
    assert eng.stats.decode_utilization > 1.0


def test_slot_reuse_and_admission_under_full_cache():
    """With every slot busy a queued request stays out; it is admitted on
    the iteration after a retirement frees its slot."""
    cfg, params = setup("yi-6b")
    reqs = mixed_requests(cfg, n=3)
    eng = ContinuousServeEngine(cfg, params, n_slots=2, max_len=32,
                                prefill_chunk=16)
    for r in reqs:
        eng.submit(r)
    waited = False
    finished: list = []
    while eng.has_work:
        before = set(eng.active_uids)
        if len(before) == eng.n_slots and eng.queue:
            waited = True  # cache full: uid 2 must wait
            assert 2 not in before
        finished.extend(eng.step())
        assert len(eng.active_uids) <= eng.n_slots
    assert waited
    assert sorted(o.uid for o in finished) == [0, 1, 2]
    # late-admitted request still matches its solo lockstep run
    ref = ServeEngine(cfg, params, max_len=32).generate(
        reqs[2].prompt[None, :], steps=reqs[2].max_new_tokens)
    out2 = next(o for o in finished if o.uid == 2)
    assert np.array_equal(out2.tokens, ref.tokens[0])


def test_stop_tokens_retire_early():
    cfg, params = setup("yi-6b")
    [req] = mixed_requests(cfg, n=1)
    eng = ContinuousServeEngine(cfg, params, n_slots=1, max_len=32,
                                prefill_chunk=8)
    [full] = eng.run([req])
    assert len(full.tokens) >= 3
    stop = int(full.tokens[2])
    eng2 = ContinuousServeEngine(cfg, params, n_slots=1, max_len=32,
                                 prefill_chunk=8)
    [cut] = eng2.run([Request(uid=0, prompt=req.prompt,
                              max_new_tokens=req.max_new_tokens,
                              stop_tokens=(stop,))])
    assert cut.finish_reason == "stop"
    first = int(np.argmax(full.tokens == stop))
    assert np.array_equal(cut.tokens, full.tokens[:first + 1])


def test_submit_validation():
    cfg, params = setup("yi-6b")
    eng = ContinuousServeEngine(cfg, params, n_slots=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        eng.submit(Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                           max_new_tokens=10))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=1, prompt=np.zeros(0, np.int32),
                           max_new_tokens=2))


# ------------------------------------------------------------------- rng
def test_greedy_consumes_no_rng(monkeypatch):
    """temperature == 0 must never touch the PRNG: seed-independent, and
    no categorical() call at all."""
    cfg, params = setup("yi-6b")
    prompts = np.arange(1, 6, dtype=np.int32)[None]

    def boom(*a, **k):
        raise AssertionError("PRNG consumed on the greedy path")

    monkeypatch.setattr(jax.random, "categorical", boom)
    monkeypatch.setattr(jax.random, "key", boom)
    eng = ServeEngine(cfg, params, max_len=16)
    a = eng.generate(prompts, steps=4, seed=0)
    b = eng.generate(prompts, steps=4, seed=123)
    assert np.array_equal(a.tokens, b.tokens)
    ceng = ContinuousServeEngine(cfg, params, n_slots=1, max_len=16)
    [out] = ceng.run([Request(uid=0, prompt=prompts[0], max_new_tokens=4,
                              seed=7)])
    assert np.array_equal(out.tokens, a.tokens[0])


def test_sampled_stream_is_seed_reproducible():
    """temperature > 0: same seed replays the stream, in both engines,
    with the continuous engine matching lockstep per request."""
    cfg, params = setup("yi-6b")
    prompts = np.arange(1, 6, dtype=np.int32)[None]
    eng = ServeEngine(cfg, params, max_len=32, temperature=1.0)
    a = eng.generate(prompts, steps=12, seed=3)
    b = eng.generate(prompts, steps=12, seed=3)
    assert np.array_equal(a.tokens, b.tokens)
    c = eng.generate(prompts, steps=12, seed=4)
    assert not np.array_equal(a.tokens, c.tokens)
    ceng = ContinuousServeEngine(cfg, params, n_slots=2, max_len=32,
                                 prefill_chunk=4)
    [out] = ceng.run([Request(uid=0, prompt=prompts[0], max_new_tokens=12,
                              temperature=1.0, seed=3)])
    assert np.array_equal(out.tokens, a.tokens[0])
