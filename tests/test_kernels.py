"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import slicing as sl
from repro.kernels import ops, ref


class TestCenteredInt8Matmul:
    @pytest.mark.parametrize("B,K,N", [
        (1, 1, 1), (8, 128, 128), (37, 700, 45), (256, 512, 256),
        (3, 2048, 17), (130, 130, 130),
    ])
    def test_shapes(self, B, K, N):
        rng = np.random.default_rng(B * 1000 + K + N)
        x = jnp.asarray(rng.integers(-127, 128, (B, K)), jnp.int8)
        w = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
        c = jnp.asarray(rng.integers(-128, 128, (N,)), jnp.int32)
        got = ops.centered_int8_matmul(x, w, c, use_pallas=True)
        want = ref.centered_int8_matmul(x, w, c)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_block_sizes(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.integers(-127, 128, (100, 300)), jnp.int8)
        w = jnp.asarray(rng.integers(-127, 128, (300, 200)), jnp.int8)
        c = jnp.asarray(rng.integers(-128, 128, (200,)), jnp.int32)
        from repro.kernels import int8_matmul as im
        for bm, bk, bn in [(8, 128, 128), (32, 256, 128), (256, 512, 256)]:
            got = im.centered_int8_matmul(x, w, c, bm=bm, bk=bk, bn=bn,
                                          interpret=True)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref.centered_int8_matmul(x, w, c)))

    def test_reconstructs_uncentered_matmul(self):
        """x @ w == x @ (w - c) + sum(x) * c  — Eq. 1 exactness."""
        rng = np.random.default_rng(8)
        w_full = rng.integers(-100, 100, (64, 16))
        c = np.round(w_full.mean(axis=0)).astype(np.int32)
        w_off = (w_full - c[None, :]).astype(np.int8)
        x = jnp.asarray(rng.integers(-127, 128, (9, 64)), jnp.int8)
        got = ops.centered_int8_matmul(x, jnp.asarray(w_off), jnp.asarray(c),
                                       use_pallas=True)
        want = np.asarray(x, np.int64) @ w_full
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)

    @hypothesis.given(st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, seed):
        rng = np.random.default_rng(seed)
        B, K, N = (int(rng.integers(1, 64)), int(rng.integers(1, 600)),
                   int(rng.integers(1, 300)))
        x = jnp.asarray(rng.integers(-127, 128, (B, K)), jnp.int8)
        w = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
        c = jnp.asarray(rng.integers(-128, 128, (N,)), jnp.int32)
        got = ops.centered_int8_matmul(x, w, c, use_pallas=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.centered_int8_matmul(x, w, c)))


class TestSlicedCrossbarKernel:
    def _mk(self, rng, n_i, n_j, B, R, C):
        xs = jnp.asarray(rng.integers(0, 16, (n_i, B, R)), jnp.int8)
        wp = jnp.asarray(rng.integers(-15, 16, (n_j, R, C)), jnp.int8)
        mults = jnp.asarray(rng.choice([1, 2, 4, 16, 64], size=(n_i, n_j)),
                            jnp.int32)
        return xs, wp, mults

    @pytest.mark.parametrize("n_i,n_j,B,R,C", [
        (1, 1, 4, 512, 64), (3, 3, 8, 512, 128), (8, 2, 2, 1024, 32),
        (2, 4, 16, 300, 200), (3, 3, 1, 1500, 7),
    ])
    def test_shapes(self, n_i, n_j, B, R, C):
        rng = np.random.default_rng(n_i + 10 * n_j + B + R + C)
        xs, wp, m = self._mk(rng, n_i, n_j, B, R, C)
        got = ops.sliced_crossbar_matmul(xs, wp, m, use_pallas=True)
        want = ref.sliced_crossbar_matmul(xs, wp, m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_adc_bounds_respected(self):
        """Saturating inputs must clamp per segment, not per total."""
        rng = np.random.default_rng(5)
        xs, wp, m = self._mk(rng, 1, 1, 2, 1024, 8)
        xs = jnp.full_like(xs, 15)
        wp = jnp.full_like(wp, 15)
        got = ops.sliced_crossbar_matmul(xs, wp, m, use_pallas=True)
        # two segments, each clamps at 63 -> 126 * mult
        np.testing.assert_array_equal(np.asarray(got),
                                      np.full((2, 8), 126 * int(m[0, 0])))

    def test_matches_crossbar_module(self):
        """Kernel path == repro.core.crossbar forward (offset term)."""
        rng = np.random.default_rng(6)
        w_u = rng.integers(0, 256, (700, 12)).astype(np.int64)
        slicing = (4, 2, 2)
        enc = co.encode(w_u, slicing)
        x = jnp.asarray(rng.integers(0, 256, (5, 700)))
        # core module full path
        psum, _ = xbar.forward(x, enc, (1,) * 8)
        # kernel path: input 1b slices x weight planes + digital center term
        n_seg, R = enc.n_segments, enc.rows_per_xbar
        x_pad = jnp.pad(x, ((0, 0), (0, n_seg * R - x.shape[1])))
        x_slices = jnp.stack([sl.crop_unsigned(x_pad, b, b).astype(jnp.int8)
                              for b in range(7, -1, -1)])
        w_planes = jnp.asarray(
            enc.planes.transpose(0, 1, 2, 3).reshape(enc.n_slices, n_seg * R,
                                                     enc.cols))
        mults = jnp.asarray(
            [[1 << (li + lw) for lw in enc.shifts] for li in range(7, -1, -1)],
            jnp.int32)
        offs = ops.sliced_crossbar_matmul(x_slices, w_planes, mults,
                                          use_pallas=True)
        got = offs + co.center_term(x, enc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(psum))

    @hypothesis.given(st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=8, deadline=None)
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        n_i, n_j = int(rng.integers(1, 4)), int(rng.integers(1, 4))
        B, R, C = (int(rng.integers(1, 9)), int(rng.integers(1, 1200)),
                   int(rng.integers(1, 150)))
        xs, wp, m = self._mk(rng, n_i, n_j, B, R, C)
        got = ops.sliced_crossbar_matmul(xs, wp, m, use_pallas=True)
        want = ref.sliced_crossbar_matmul(xs, wp, m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestEdgeShapesBothPaths:
    """Edge shapes through BOTH dispatch paths (Pallas interpret and the
    XLA fallback), each checked against an independent numpy oracle — so
    a shared bug in kernel *and* ``ref`` cannot hide."""

    @staticmethod
    def _np_centered(x, w, c):
        xs = np.asarray(x, np.int64)
        y = xs @ np.asarray(w, np.int64)
        return y + xs.sum(axis=1, keepdims=True) * np.asarray(c, np.int64)

    @staticmethod
    def _np_sliced(xs, wp, m, rows_per_xbar=512, lo=-64, hi=63):
        n_i, B, R = xs.shape
        n_j, _, C = wp.shape
        n_seg = -(-R // rows_per_xbar)
        out = np.zeros((B, C), np.int64)
        for i in range(n_i):
            for j in range(n_j):
                for s in range(n_seg):
                    r0, r1 = s * rows_per_xbar, min((s + 1) * rows_per_xbar, R)
                    cs = (np.asarray(xs[i, :, r0:r1], np.int64)
                          @ np.asarray(wp[j, r0:r1], np.int64))
                    out += np.clip(cs, lo, hi) * int(m[i, j])
        return out

    @pytest.mark.parametrize("use_pallas", [True, False],
                             ids=["interpret", "xla-fallback"])
    @pytest.mark.parametrize("B,K,N", [
        (1, 1, 1),       # full singleton
        (1, 513, 129),   # B=1, K/N one past a block multiple
        (5, 7, 1),       # single output column
        (2, 130, 257),   # N not a multiple of the 128 tile
        (9, 1, 130),     # K=1 (degenerate contraction)
    ])
    def test_centered_int8_edges(self, B, K, N, use_pallas):
        rng = np.random.default_rng(B * 7919 + K * 31 + N)
        x = jnp.asarray(rng.integers(-127, 128, (B, K)), jnp.int8)
        w = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
        c = jnp.asarray(rng.integers(-128, 128, (N,)), jnp.int32)
        got = ops.centered_int8_matmul(x, w, c, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(got, np.int64),
                                      self._np_centered(x, w, c))

    @pytest.mark.parametrize("use_pallas", [True, False],
                             ids=["interpret", "xla-fallback"])
    @pytest.mark.parametrize("n_i,n_j,B,R,C", [
        (1, 1, 1, 1, 1),      # minimal everything
        (1, 1, 1, 513, 3),    # R one past rows_per_xbar (2 ragged segments)
        (2, 3, 1, 700, 130),  # B=1, R and C both off-tile
        (1, 2, 4, 1025, 1),   # C=1, R spills into a third segment
    ])
    def test_sliced_crossbar_edges(self, n_i, n_j, B, R, C, use_pallas):
        rng = np.random.default_rng(n_i * 131 + n_j * 17 + B + R + C)
        xs = jnp.asarray(rng.integers(0, 16, (n_i, B, R)), jnp.int8)
        wp = jnp.asarray(rng.integers(-15, 16, (n_j, R, C)), jnp.int8)
        m = jnp.asarray(rng.choice([1, 2, 4, 16, 64], size=(n_i, n_j)),
                        jnp.int32)
        got = ops.sliced_crossbar_matmul(xs, wp, m, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(got, np.int64),
                                      self._np_sliced(xs, wp, m))

    def test_saturating_segment_boundary(self):
        """R not divisible by rows_per_xbar with saturating sums: the
        ragged tail segment must clamp independently of the full one."""
        xs = jnp.full((1, 2, 700), 15, jnp.int8)
        wp = jnp.full((1, 700, 4), 15, jnp.int8)
        m = jnp.ones((1, 1), jnp.int32)
        for use_pallas in (True, False):
            got = ops.sliced_crossbar_matmul(xs, wp, m,
                                             use_pallas=use_pallas)
            # both segments (512 rows + 188-row tail) saturate at 63
            np.testing.assert_array_equal(np.asarray(got),
                                          np.full((2, 4), 126))
