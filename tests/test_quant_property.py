"""Hypothesis property tests for the quantization substrate."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.quant import quantize as q


def _w(seed, rows, cols, scale, offset):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(offset, scale, (rows, cols)), jnp.float32)


class TestSymmetricQuant:
    @hypothesis.given(st.integers(0, 2**31 - 1), st.floats(1e-3, 10.0))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_roundtrip_bounded(self, seed, scale):
        w = _w(seed, 64, 8, scale, 0.0)
        w_q, s = q.quantize_weights_per_channel(w)
        back = w_q.astype(jnp.float32) * s
        step = np.asarray(s)
        err = np.abs(np.asarray(back - w))
        assert (err <= step / 2 + 1e-6).all()

    @hypothesis.given(st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_codes_in_range(self, seed):
        w = _w(seed, 32, 4, 1.0, 0.0)
        w_q, _ = q.quantize_weights_per_channel(w)
        assert int(jnp.max(jnp.abs(w_q.astype(jnp.int32)))) <= 127


class TestCenteredQuant:
    @hypothesis.given(st.integers(0, 2**31 - 1),
                      st.floats(-5.0, 5.0), st.floats(1e-2, 2.0))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_roundtrip_bounded(self, seed, offset, scale):
        """Centered codes reconstruct within half a (finer) step even for
        arbitrarily offset channels — the Eq. 1 payoff."""
        w = _w(seed, 64, 8, scale, offset)
        w_off, centers, s = q.quantize_weights_centered(w)
        back = (w_off.astype(jnp.float32) + centers.astype(jnp.float32)) * s
        err = np.abs(np.asarray(back - w))
        assert (err <= np.asarray(s) / 2 + np.asarray(s) * 1e-3 + 1e-6).all()

    @hypothesis.given(st.integers(0, 2**31 - 1), st.floats(0.5, 8.0))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_centered_step_never_coarser(self, seed, offset):
        """half-range/127 <= absmax/127 always; strictly finer when offset."""
        w = _w(seed, 64, 8, 0.3, offset)
        _, s_sym = q.quantize_weights_per_channel(w)
        _, _, s_cen = q.quantize_weights_centered(w)
        assert (np.asarray(s_cen) <= np.asarray(s_sym) + 1e-9).all()
        # with a large offset the centered scale is much finer
        assert np.asarray(s_cen).mean() < 0.8 * np.asarray(s_sym).mean()

    @hypothesis.given(st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_offsets_fit_int8(self, seed):
        w = _w(seed, 48, 6, 1.0, 3.0)
        w_off, _, _ = q.quantize_weights_centered(w)
        assert w_off.dtype == jnp.int8


class TestRequant:
    @hypothesis.given(st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_requant_relu_range(self, seed):
        rng = np.random.default_rng(seed)
        y = jnp.asarray(rng.normal(0, 2, (16, 8)), jnp.float32)
        lq = q.LayerQuant(
            w_scale=jnp.ones((8,)), x_scale=jnp.asarray(1.0),
            x_zero_point=jnp.asarray(0), x_signed=False,
            out_scale=jnp.asarray(0.05), out_zero_point=jnp.asarray(0),
            bias=None)
        codes = q.requantize_outputs(y, lq, relu=True)
        assert int(jnp.min(codes)) >= 0 and int(jnp.max(codes)) <= 255
        codes = q.requantize_outputs(y, lq, relu=False)
        assert int(jnp.min(codes)) >= -128 and int(jnp.max(codes)) <= 127
