"""Crossbar simulator, speculation, and PIM-linear exactness tests."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import pim_linear as pl
from repro.core import slicing as sl
from repro.core import speculation as spec


def _rand_layer(rng, rows, cols, w_std=20):
    w_signed = np.clip(rng.normal(0, w_std, size=(rows, cols)), -127, 127)
    w_u = (np.round(w_signed) + 128).astype(np.int64)
    x = rng.integers(0, 256, size=(4, rows))
    return w_u, jnp.asarray(x)


class TestCrossbarIdeal:
    """With the ADC bypassed, sliced arithmetic must be *exact* (Table 1)."""

    @pytest.mark.parametrize("slicing", [(4, 4), (4, 2, 2), (2, 2, 2, 2), (1,) * 8])
    @pytest.mark.parametrize("rows", [64, 512, 900])
    def test_exact_reconstruction(self, slicing, rows):
        rng = np.random.default_rng(0)
        w_u, x = _rand_layer(rng, rows, 6)
        enc = co.encode(w_u, slicing)
        psum, _ = xbar.forward(x, enc, (1,) * 8, ideal=True)
        want = xbar.matmul_reference(x, jnp.asarray(w_u))
        np.testing.assert_array_equal(np.asarray(psum), np.asarray(want))

    @pytest.mark.parametrize("input_slicing", [(4, 2, 2), (4, 4), (2,) * 4])
    def test_exact_any_input_slicing(self, input_slicing):
        rng = np.random.default_rng(1)
        w_u, x = _rand_layer(rng, 300, 5)
        enc = co.encode(w_u, (4, 2, 2))
        psum, _ = xbar.forward(x, enc, input_slicing, ideal=True)
        want = xbar.matmul_reference(x, jnp.asarray(w_u))
        np.testing.assert_array_equal(np.asarray(psum), np.asarray(want))

    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    @hypothesis.settings(max_examples=8, deadline=None)
    def test_exact_property(self, seed):
        rng = np.random.default_rng(seed)
        rows = int(rng.integers(2, 800))
        cols = int(rng.integers(1, 5))
        slicing = sl.enumerate_slicings()[int(rng.integers(0, 108))]
        w_u = rng.integers(0, 256, size=(rows, cols), dtype=np.int64)
        x = jnp.asarray(rng.integers(0, 256, size=(2, rows)))
        enc = co.encode(w_u, slicing, mode="center")
        psum, _ = xbar.forward(x, enc, (1,) * 8, ideal=True)
        want = xbar.matmul_reference(x, jnp.asarray(w_u))
        np.testing.assert_array_equal(np.asarray(psum), np.asarray(want))


class TestADC:
    def test_clip_bounds(self):
        vals = jnp.asarray([-1000, -65, -64, 0, 63, 64, 1000])
        out, sat = adc_lib.convert(vals, adc_lib.RAELLA_ADC)
        np.testing.assert_array_equal(np.asarray(out), [-64, -64, -64, 0, 63, 63, 63])
        np.testing.assert_array_equal(np.asarray(sat),
                                      [True, True, True, False, True, True, True])

    def test_lsb_fidelity(self):
        """Step size 1: in-range sums convert exactly (paper §3)."""
        vals = jnp.arange(-64, 64)
        out, sat = adc_lib.convert(vals, adc_lib.RAELLA_ADC)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(vals))

    def test_noise_changes_output(self):
        vals = jnp.zeros((1000,), jnp.int32)
        pos = jnp.full((1000,), 200, jnp.int32)
        neg = jnp.full((1000,), 200, jnp.int32)
        out, _ = adc_lib.convert(vals, adc_lib.RAELLA_ADC, noise_level=0.12,
                                 pos_sum=pos, neg_sum=neg,
                                 key=jax.random.key(0))
        assert float(jnp.std(out.astype(jnp.float32))) > 0.5


class TestSaturationBehaviour:
    def test_centered_saturates_less_than_zero_offset(self):
        """The paper's core fidelity claim (Fig. 5, Table 4)."""
        rng = np.random.default_rng(7)
        # skewed filter: mostly-negative weights
        w_signed = np.clip(rng.normal(-35, 20, size=(512, 16)), -127, 127)
        w_u = (np.round(w_signed) + 128).astype(np.int64)
        x = jnp.asarray(rng.integers(0, 256, size=(8, 512)))
        enc_c = co.encode(w_u, (4, 2, 2), mode="center")
        enc_z = co.encode(w_u, (4, 2, 2), mode="zero")
        _, st_c = xbar.forward(x, enc_c, (1,) * 8)
        _, st_z = xbar.forward(x, enc_z, (1,) * 8)
        assert int(st_c.saturations) < int(st_z.saturations)

    def test_low_saturation_rate_when_centered(self):
        rng = np.random.default_rng(8)
        w_signed = np.clip(rng.normal(0, 25, size=(512, 32)), -127, 127)
        w_u = (np.round(w_signed) + 128).astype(np.int64)
        # right-skewed unsigned inputs (post-ReLU-like)
        x = jnp.asarray(np.clip(rng.exponential(30, size=(8, 512)), 0, 255).astype(np.int64))
        enc = co.encode(w_u, (1,) * 8, mode="center")
        _, st = xbar.forward(x, enc, (1,) * 8)
        rate = int(st.saturations) / int(st.conversions_possible)
        assert rate < 0.01  # minimal slicing: ~1e-7 in paper; allow slack


class TestSpeculation:
    def test_matches_static_when_no_saturation(self):
        """If nothing saturates, speculation == static slicing == ideal."""
        rng = np.random.default_rng(3)
        w_signed = np.clip(rng.normal(0, 6, size=(64, 4)), -127, 127)
        w_u = (np.round(w_signed) + 128).astype(np.int64)
        x = jnp.asarray(rng.integers(0, 40, size=(3, 64)))
        enc = co.encode(w_u, (1,) * 8, mode="center")
        psum_spec, st = spec.forward(x, enc)
        want = xbar.matmul_reference(x, jnp.asarray(w_u))
        # center term means sums are small; check exactness holds
        np.testing.assert_array_equal(np.asarray(psum_spec), np.asarray(want))

    def test_recovery_reduces_error_vs_no_recovery(self):
        """Speculation+recovery must be at least as accurate as aggressive
        static (4,2,2) input slicing alone."""
        rng = np.random.default_rng(4)
        w_signed = np.clip(rng.normal(10, 45, size=(512, 24)), -127, 127)
        w_u = (np.round(w_signed) + 128).astype(np.int64)
        x = jnp.asarray(rng.integers(0, 256, size=(8, 512)))
        enc = co.encode(w_u, (4, 2, 2), mode="center")
        want = np.asarray(xbar.matmul_reference(x, jnp.asarray(w_u)), np.int64)
        psum_spec, st = spec.forward(x, enc)
        psum_aggr, _ = xbar.forward(x, enc, (4, 2, 2))
        err_spec = np.abs(np.asarray(psum_spec, np.int64) - want).mean()
        err_aggr = np.abs(np.asarray(psum_aggr, np.int64) - want).mean()
        assert err_spec <= err_aggr

    def test_convert_savings(self):
        """Speculation should need far fewer converts than recovery-only
        (paper: ~60% reduction at ~2% failure rate). Uses realistic DNN-like
        distributions: peaked (Laplacian) weights, sparse right-skewed inputs."""
        rng = np.random.default_rng(5)
        w_signed = np.clip(rng.laplace(0, 10, size=(512, 32)), -127, 127)
        w_u = (np.round(w_signed) + 128).astype(np.int64)
        x_raw = rng.exponential(12, size=(8, 512)) * (rng.random((8, 512)) > 0.4)
        x = jnp.asarray(np.clip(x_raw, 0, 255).astype(np.int64))
        enc = co.encode(w_u, (4, 2, 2), mode="center")
        _, st = spec.forward(x, enc)
        saving = 1.0 - float(st.adc_converts) / float(st.no_spec_converts)
        assert saving > 0.45
        assert float(st.failure_rate) < 0.15
        assert st.cycles == 11  # 3 speculation + 8 recovery (paper §6.1.1)


class TestPimLinear:
    def test_exact_path_close_to_float(self):
        rng = np.random.default_rng(6)
        w = jnp.asarray(rng.normal(0, 0.05, size=(256, 32)), jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, size=(10, 256)), 0),
                        jnp.float32)
        plan = pl.prepare(w, x, weight_slicing=(4, 2, 2), speculation=True)
        y = pl.forward_exact(x, plan)
        y_ref = x @ w
        rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
        assert rel < 0.05

    def test_signed_inputs_two_pass(self):
        rng = np.random.default_rng(9)
        w = jnp.asarray(rng.normal(0, 0.05, size=(128, 16)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 0.5, size=(6, 128)), jnp.float32)  # signed
        plan = pl.prepare(w, x, weight_slicing=(2, 2, 2, 2), speculation=False)
        assert plan.lq.x_signed
        y = pl.forward_exact(x, plan)
        y_ref = x @ w
        rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
        assert rel < 0.05

    def test_int_reference_matches_dequant_algebra(self):
        rng = np.random.default_rng(10)
        w = jnp.asarray(rng.normal(0, 0.1, size=(64, 8)), jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.2, 0.3, size=(4, 64)), 0),
                        jnp.float32)
        plan = pl.prepare(w, x, speculation=False)
        y_ref = pl.forward_int_reference(x, plan)
        rel = float(jnp.linalg.norm(y_ref - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.03  # pure 8b quantization error

    def test_exact_equals_int_reference_when_ideal_conditions(self):
        """Small weights/inputs -> no saturation -> exact sim == int ref."""
        rng = np.random.default_rng(11)
        w = jnp.asarray(rng.normal(0, 0.02, size=(100, 12)), jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.1, 0.1, size=(5, 100)), 0),
                        jnp.float32)
        plan = pl.prepare(w, x, weight_slicing=(1,) * 8, speculation=False)
        y_sim = pl.forward_exact(x, plan, input_slicing=(1,) * 8)
        y_ref = pl.forward_int_reference(x, plan)
        np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_ref),
                                   rtol=0, atol=1e-5)

    def test_fast_path_beats_symmetric_quant_for_skewed_weights(self):
        """Centered fast path (Eq. 1 on TPU) should reduce quantization error
        for skewed per-channel weight distributions."""
        rng = np.random.default_rng(12)
        base = rng.normal(0, 0.02, size=(256, 32))
        skew = rng.uniform(0.2, 0.5, size=(1, 32))  # big per-channel offsets
        w = jnp.asarray(base + skew, jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.3, 0.3, size=(16, 256)), 0),
                        jnp.float32)
        plan = pl.prepare(w, x, speculation=False)
        y_fast = pl.forward_fast(x, plan)
        y_float = x @ w
        # symmetric int8 reference
        y_sym = pl.forward_int_reference(x, plan)
        err_fast = float(jnp.abs(y_fast - y_float).mean())
        err_sym = float(jnp.abs(y_sym - y_float).mean())
        assert err_fast < err_sym
