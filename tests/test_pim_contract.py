"""Exact-vs-reference contract for RaellaLinear (paper Table 1 / §5.1).

With analog noise off and a non-saturating ADC, the full accelerator
simulation (Center+Offset encoding, sliced crossbars, speculation,
signed two-pass) must reproduce the ideal 8b-quantized layer *bit
exactly* — the entire datapath is then pure integer arithmetic with a
lossless converter. The fast TPU path uses a different (centered,
per-channel asymmetric) quantizer, so it matches within the combined
dequantization step of the two quantizers, not bit-exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adc as adc_lib
from repro.core import pim_linear as pl

# 24-bit signed range holds any 8b x 8b x 1024-row column sum: the ADC
# converts losslessly and never saturates
WIDE_ADC = adc_lib.ADCConfig(bits=24, signed=True)

ROWS, COLS, BATCH = 96, 10, 5


def _layer(signed: bool, seed: int = 42):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.08, size=(ROWS, COLS)), jnp.float32)
    xs = rng.normal(0.2, 0.4, size=(BATCH, ROWS))
    if not signed:
        xs = np.maximum(xs, 0)
    return w, jnp.asarray(xs, jnp.float32)


@pytest.mark.parametrize("speculation", [False, True],
                         ids=["static", "speculative"])
@pytest.mark.parametrize("signed", [False, True],
                         ids=["unsigned", "signed"])
class TestExactEqualsReference:
    def test_bit_exact_at_zero_noise(self, speculation, signed):
        w, x = _layer(signed)
        plan = pl.prepare(w, x, weight_slicing=(4, 2, 2), adc=WIDE_ADC,
                          speculation=speculation)
        assert plan.lq.x_signed == signed
        y = pl.forward_exact(x, plan, noise_level=0.0)
        y_ref = pl.forward_int_reference(x, plan)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))

    def test_fast_within_dequant_tolerance(self, speculation, signed):
        w, x = _layer(signed)
        plan = pl.prepare(w, x, weight_slicing=(4, 2, 2), adc=WIDE_ADC,
                          speculation=speculation)
        y_fast = np.asarray(pl.forward_fast(x, plan))
        y_ref = np.asarray(pl.forward_int_reference(x, plan))
        # worst-case combined rounding of the two weight quantizers:
        # every row contributes at most |x|_max * (step_sym + step_cen) / 2
        step = np.asarray(plan.lq.w_scale) + np.asarray(plan.fast_scale)
        bound = ROWS * float(jnp.abs(x).max()) * step / 2
        assert (np.abs(y_fast - y_ref) <= bound[None, :]).all()
        # and both stay close to the float layer
        y_float = np.asarray(x @ w)
        rel = np.linalg.norm(y_fast - y_float) / np.linalg.norm(y_float)
        assert rel < 0.03


class TestNoiseAndSaturationBreakExactness:
    """Negative controls: the bit-exact claim is specific to noise-free,
    non-saturating conditions."""

    def test_narrow_adc_saturates_away_from_reference(self):
        rng = np.random.default_rng(7)
        # skewed weights + zero-offset encoding (the differential baseline
        # RAELLA replaces): column sums overflow the 7b ADC
        w = jnp.asarray(rng.normal(-0.3, 0.15, size=(512, 8)), jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.4, 0.4, size=(5, 512)), 0),
                        jnp.float32)
        plan = pl.prepare(w, x, weight_slicing=(4, 2, 2),
                          adc=adc_lib.RAELLA_ADC, speculation=False,
                          encode_mode="zero")
        y = pl.forward_exact(x, plan, noise_level=0.0)
        y_ref = pl.forward_int_reference(x, plan)
        assert np.abs(np.asarray(y) - np.asarray(y_ref)).max() > 0

    def test_noise_perturbs_output(self):
        import jax
        w, x = _layer(signed=False, seed=8)
        plan = pl.prepare(w, x, weight_slicing=(4, 2, 2), adc=WIDE_ADC,
                          speculation=False)
        y0 = pl.forward_exact(x, plan, noise_level=0.0)
        y1 = pl.forward_exact(x, plan, noise_level=0.5,
                              key=jax.random.key(0))
        assert np.abs(np.asarray(y1) - np.asarray(y0)).max() > 0
