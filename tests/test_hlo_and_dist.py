"""Loop-aware HLO analysis, sharding rules, and int8 KV-cache tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import sharding as dsh
from repro.launch import hlo_analysis as hlo
from repro.models import transformer as T


class TestHloAnalysis:
    def test_scan_flops_exact(self):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)).compile()
        costs = hlo.analyze(c.as_text())
        want = 5 * 2 * 64 * 128 * 128
        assert costs.flops == pytest.approx(want, rel=1e-6)
        # XLA's own analysis counts the loop body once — ours must not
        assert c.cost_analysis()["flops"] < costs.flops

    def test_nested_scan_flops(self):
        def f(x, ws):
            def outer(c, wpair):
                def inner(ci, w):
                    return ci @ w, None
                c2, _ = jax.lax.scan(inner, c, wpair)
                return c2, None
            y, _ = jax.lax.scan(outer, x, ws)
            return y
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((3, 2, 64, 64), jnp.float32)).compile()
        costs = hlo.analyze(c.as_text())
        want = 6 * 2 * 32 * 64 * 64
        assert costs.flops == pytest.approx(want, rel=1e-6)

    def test_roofline_terms(self):
        t = hlo.roofline_terms(197e12, 0.0, 0.0)
        assert t["bottleneck"] == "compute"
        assert t["roofline_fraction"] == pytest.approx(1.0)
        t = hlo.roofline_terms(1e12, 819e9 * 2, 0.0)
        assert t["bottleneck"] == "memory"


class TestShardingRules:
    def test_spec_for_resolves_axes(self):
        mesh = jax.sharding.AbstractMesh((2, 2), ("data", "model"))
        with dsh.axis_rules(dsh.DEFAULT_RULES):
            assert dsh.spec_for(("batch", "seq"), mesh) == P("data", "model")
            # duplicate mesh-axis use degrades to replication
            assert dsh.spec_for(("seq", "vocab"), mesh) == P("model")

    def test_fit_spec_drops_indivisible(self):
        mesh = jax.sharding.AbstractMesh((2, 2), ("data", "model"))
        spec = dsh.fit_spec_to_shape(P("data", "model"), (3, 8), mesh)
        assert spec == P(None, "model")
        spec = dsh.fit_spec_to_shape(P(("data", "model")), (6,), mesh)
        assert spec == P("data")  # 6 % 2 == 0 but 6 % 4 != 0

    def test_serve_rules_weights_stationary(self):
        mesh = jax.sharding.AbstractMesh((2, 2), ("data", "model"))
        with dsh.axis_rules(dsh.SERVE_RULES):
            # weight output dims shard over the whole mesh; no fsdp dim
            assert dsh.spec_for(("fsdp", "tp"), mesh) == P(None, ("data", "model"))
            assert dsh.spec_for(("batch", None), mesh) == P()


class TestInt8KvCache:
    def test_decode_matches_forward_within_quant_error(self):
        cfg = configs.get("yi-6b").reduced()
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        params, _ = T.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                  cfg.vocab_size)
        full = T.forward(params, cfg, toks)
        logits, state = T.prefill(params, cfg, toks[:, :6], max_len=8)
        # int8 cache introduces bounded quantization error only
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, 5]), rtol=0.2, atol=0.2)
        for t in range(6, 8):
            logits, state = T.decode_step(params, cfg, state, toks[:, t:t + 1])
            np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                       np.asarray(full[:, t]),
                                       rtol=0.2, atol=0.2)

    def test_cache_is_int8(self):
        cfg = configs.get("yi-6b").reduced()
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        state = T.init_decode_state(cfg, 2, 16)
        c = state["caches"][0]
        assert c["k"].dtype == jnp.int8
        assert "k_scale" in c

    def test_quantize_roundtrip(self):
        t = jax.random.normal(jax.random.key(0), (3, 4, 2, 16))
        q, s = T._quantize_kv(t)
        back = T._dequantize_kv(q, s, jnp.float32)
        np.testing.assert_allclose(np.asarray(back), np.asarray(t),
                                   atol=float(jnp.abs(t).max()) / 100)
