"""Telemetry subsystem (``repro.obs``): registry/exposition/tracing unit
behavior plus the serve-stack contracts — greedy outputs bit-identical
with telemetry on vs off, live counters matching both ``ServeStats`` and
the jit-collected ``SpeculationStats`` totals, and the pJ/token gauge
agreeing with ``repro.core.energy``."""

import dataclasses
import json
import pathlib
import sys

import jax
import numpy as np
import pytest

from repro import configs, obs
from repro.core import energy as en
from repro.models import layers as L
from repro.models import pim
from repro.models import transformer as T
from repro.obs.metrics import MetricsRegistry
from repro.obs.serve import NULL_TELEMETRY, STEP_BUCKETS, ServeTelemetry
from repro.obs.tracing import Tracer
from repro.serve import ContinuousServeEngine, PagedServeEngine, Request
from repro.serve.scheduler import EngineStats, ServeStats

ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:  # `benchmarks` package lives at the repo root
    sys.path.insert(0, str(ROOT))


_CACHE: dict = {}


def setup(arch: str = "yi-6b"):
    if arch not in _CACHE:
        cfg = configs.get(arch).reduced()
        params, _ = T.init_params(cfg, jax.random.key(0))
        _CACHE[arch] = (cfg, params)
    return _CACHE[arch]


def mixed_requests(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    plens = [3, 7, 5, 9][:n]
    steps = [6, 3, 9, 4][:n]
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plens[i]).astype(np.int32),
                    max_new_tokens=steps[i])
            for i in range(n)]


# ------------------------------------------------------------- metrics
def test_histogram_bucket_math():
    """Cumulative ``le`` semantics: a value lands in every bucket whose
    upper bound is >= it (inclusive), plus +Inf."""
    r = MetricsRegistry()
    h = r.histogram("h_seconds", "test", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 3.0, 10.0):
        h.observe(v)
    s = h.get()
    assert s["counts"] == [2, 2, 3, 4]      # le=1, le=2, le=5, +Inf
    assert s["count"] == 4
    assert s["sum"] == pytest.approx(14.5)
    # bucket upper bounds are inclusive: 1.0 counted under le=1
    assert s["counts"][0] == 2


def test_counter_gauge_labels_and_guards():
    r = MetricsRegistry()
    c = r.counter("req_total", "requests", ("engine",))
    c.inc(engine="paged")
    c.inc(2, engine="continuous")
    assert c.get(engine="paged") == 1
    assert c.get(engine="continuous") == 2
    assert c.get(engine="other") == 0.0     # untouched series reads 0
    with pytest.raises(ValueError):
        c.inc(-1, engine="paged")           # counters only go up
    with pytest.raises(ValueError):
        c.inc(engine="paged", extra="x")    # undeclared label name
    g = r.gauge("blocks", "pool")
    g.set(7)
    g.inc(-3)
    assert g.get() == 4
    # idempotent re-declaration returns the same metric ...
    assert r.counter("req_total", "requests", ("engine",)) is c
    # ... but schema drift is refused
    with pytest.raises(ValueError):
        r.gauge("req_total", "requests", ("engine",))
    with pytest.raises(ValueError):
        r.counter("req_total", "requests", ("engine", "reason"))
    with pytest.raises(ValueError):
        r.histogram("h", buckets=(1.0, 1.0))


def test_disabled_registry_is_noop():
    r = MetricsRegistry(enabled=False)
    c = r.counter("x_total", "x", ("engine",))
    c.inc(5, engine="paged")
    r.histogram("h_seconds").observe(0.1)
    assert c.get(engine="paged") == 0.0
    assert r.snapshot() == {}
    assert obs.to_prometheus(r) == "\n"


def test_prometheus_exposition_golden():
    """Byte-exact text exposition: HELP/TYPE headers, label escaping,
    cumulative ``le`` buckets, ``_sum``/``_count``."""
    r = MetricsRegistry()
    r.counter("req_total", "requests served", ("engine",)).inc(
        3, engine="paged")
    r.gauge("pool_frac", "pool occupancy").set(0.25)
    h = r.histogram("lat_seconds", "latency", ("engine",),
                    buckets=(0.5, 1.0))
    h.observe(0.2, engine="paged")
    h.observe(2.0, engine="paged")
    assert obs.to_prometheus(r) == (
        "# HELP lat_seconds latency\n"
        "# TYPE lat_seconds histogram\n"
        'lat_seconds_bucket{engine="paged",le="0.5"} 1\n'
        'lat_seconds_bucket{engine="paged",le="1"} 1\n'
        'lat_seconds_bucket{engine="paged",le="+Inf"} 2\n'
        'lat_seconds_sum{engine="paged"} 2.2\n'
        'lat_seconds_count{engine="paged"} 2\n'
        "# HELP pool_frac pool occupancy\n"
        "# TYPE pool_frac gauge\n"
        "pool_frac 0.25\n"
        "# HELP req_total requests served\n"
        "# TYPE req_total counter\n"
        'req_total{engine="paged"} 3\n')


def test_snapshot_round_trips_json():
    r = MetricsRegistry()
    r.counter("c_total", "c", ("k",)).inc(1, k='a"b\n')
    r.histogram("h_seconds", "h").observe(0.01)
    snap = json.loads(json.dumps(r.snapshot()))
    assert snap["c_total"]["type"] == "counter"
    assert snap["c_total"]["series"][0]["labels"] == {"k": 'a"b\n'}
    assert snap["h_seconds"]["buckets"][0] == obs.DEFAULT_BUCKETS[0]
    # escaping happens only at the exposition face
    assert '\\"b\\n' in obs.to_prometheus(r)


# ------------------------------------------------------------- tracing
def test_tracer_chrome_trace_valid():
    """Deterministic clock; the written document is valid JSON in Chrome
    Trace Event format (the keys Perfetto requires per phase)."""
    t = [0.0]

    def clock():
        t[0] += 10.0
        return t[0]

    tr = Tracer(clock_us=clock)
    tr.name_track(0, "engine")
    tr.name_track(3, "request 2")
    with tr.span("decode_step", n_live=2):
        tr.instant("first_token", tid=3, uid=2)
    tr.complete("queue_wait", 5.0, 12.5, tid=3, uid=2)
    doc = json.loads(json.dumps(tr.chrome_trace()))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert [m["args"]["name"] for m in metas] == ["engine", "request 2"]
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] in ("X", "i"):
            assert isinstance(e["ts"], float)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    span = next(e for e in evs if e["name"] == "decode_step")
    assert (span["ts"], span["dur"]) == (10.0, 20.0)   # clock ticks 10us
    assert span["args"] == {"n_live": 2}
    inst = next(e for e in evs if e["name"] == "first_token")
    assert inst["ph"] == "i" and inst["tid"] == 3


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    tr.name_track(0, "engine")
    with tr.span("x"):
        tr.instant("y")
    assert tr.events() == []
    assert tr.chrome_trace()["traceEvents"] == []


# ------------------------------------------------------- serve binding
def test_stats_snapshot_parity_across_engines():
    """ONE stats schema: the paged engine shares the ServeStats dataclass
    (EngineStats is an alias, not a fork) and snapshot() covers every
    declared counter plus the derived utilization."""
    assert EngineStats is ServeStats
    cfg, params = setup()
    cont = ContinuousServeEngine(cfg, params, n_slots=2, max_len=32)
    paged = PagedServeEngine(cfg, params, n_slots=2, max_len=32,
                             block_size=4)
    assert type(cont.stats) is type(paged.stats) is ServeStats
    field_names = {f.name for f in dataclasses.fields(ServeStats)}
    for eng in (cont, paged):
        snap = eng.stats.snapshot()
        assert set(snap) == field_names | {"decode_utilization"}
    # record_stats mirrors the full snapshot as gauges
    tel = ServeTelemetry(engine="paged")
    tel.record_stats(paged.stats)
    snap = obs.snapshot(tel.registry)
    for k in field_names | {"decode_utilization"}:
        assert f"repro_serve_stats_{k}" in snap


@pytest.mark.parametrize("engine_cls", [ContinuousServeEngine,
                                        PagedServeEngine])
def test_greedy_bit_identical_with_telemetry(engine_cls):
    """The acceptance contract: threading a live ServeTelemetry (metrics
    + tracing on) through an engine changes no output token."""
    cfg, params = setup()
    kw = dict(n_slots=2, max_len=32, prefill_chunk=4)
    if engine_cls is PagedServeEngine:
        kw["block_size"] = 4
    base = engine_cls(cfg, params, **kw).run(mixed_requests(cfg))
    tel = ServeTelemetry(engine="test", tracing=True)
    eng = engine_cls(cfg, params, telemetry=tel, **kw)
    outs = eng.run(mixed_requests(cfg))
    assert [o.uid for o in outs] == [o.uid for o in base]
    for a, b in zip(outs, base):
        np.testing.assert_array_equal(a.tokens, b.tokens)

    # live counters agree with the engine's own ServeStats
    r = tel.registry
    lab = {"engine": "test"}
    st = eng.stats
    assert r.counter("repro_serve_decode_steps_total", "",
                     ("engine",)).get(**lab) == st.decode_steps
    assert r.counter("repro_serve_prefill_chunks_total", "",
                     ("engine",)).get(**lab) == st.prefill_chunks
    assert r.counter("repro_serve_tokens_generated_total", "",
                     ("engine",)).get(**lab) == sum(
        len(o.tokens) for o in outs)
    done = r.counter("repro_serve_requests_completed_total", "",
                     ("engine", "reason"))
    assert sum(v for _, v in done.series()) == len(outs) == st.completed
    # every request observed one TTFT and one e2e latency
    for name in ("repro_serve_ttft_seconds", "repro_serve_e2e_seconds",
                 "repro_serve_queue_wait_seconds"):
        assert r.histogram(name, "", ("engine",)).get(
            **lab)["count"] == len(outs)
    assert r.histogram("repro_serve_tpot_seconds", "", ("engine",),
                       buckets=STEP_BUCKETS).get(**lab)["count"] == sum(
        len(o.tokens) - 1 for o in outs)

    # the span log is a loadable Chrome trace with per-request lanes
    doc = json.loads(json.dumps(tel.tracer.chrome_trace()))
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"admission", "decode_step", "prefill_chunk", "queue_wait",
            "request", "first_token", "submit"} <= names
    req_events = [e for e in doc["traceEvents"]
                  if e["name"] == "request"]
    assert sorted(e["args"]["uid"] for e in req_events) == [
        o.uid for o in outs]
    for e in req_events:        # request lane convention: tid = uid + 1
        assert e["tid"] == e["args"]["uid"] + 1
    # exposition of the full serve registry parses as one text block
    assert obs.to_prometheus(r).startswith("# HELP")


def test_paged_pool_metrics_under_pressure():
    """A tight block pool drives the eviction/wait/pool hooks; counters
    mirror ServeStats exactly and outputs still match the no-telemetry
    run (eviction-by-recompute replays identical tokens)."""
    cfg, params = setup()
    # 8 blocks is the floor (max_len/block_size); three 17-token requests
    # need 5 blocks each, so admission queues and decode growth evicts
    kw = dict(n_slots=3, max_len=32, prefill_chunk=4, block_size=4,
              n_blocks=8)
    rng = np.random.default_rng(3)

    def reqs():
        return [Request(uid=i, prompt=p, max_new_tokens=8)
                for i, p in enumerate(rng.integers(
                    0, cfg.vocab_size, (3, 9)).astype(np.int32))]

    trace = reqs()
    base = PagedServeEngine(cfg, params, **kw).run(trace)
    tel = ServeTelemetry(engine="paged")
    eng = PagedServeEngine(cfg, params, telemetry=tel, **kw)
    outs = eng.run(trace)
    for a, b in zip(outs, base):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    st, r, lab = eng.stats, tel.registry, {"engine": "paged"}
    assert st.admission_waits + st.evictions > 0   # pressure happened
    assert r.counter("repro_serve_admission_waits_total", "",
                     ("engine",)).get(**lab) == st.admission_waits
    assert r.counter("repro_serve_evictions_total", "",
                     ("engine",)).get(**lab) == st.evictions
    assert r.gauge("repro_serve_peak_blocks_in_use", "",
                   ("engine",)).get(**lab) == st.peak_blocks_in_use
    assert r.gauge("repro_serve_blocks_in_use", "",
                   ("engine",)).get(**lab) == 0    # drained pool


# ----------------------------------------------------------- pim depth
def test_record_pim_totals_derived_gauges():
    """Derived per-token gauges match the §2.5 component energy model
    applied to the accumulated counters (two folds accumulate)."""
    r = MetricsRegistry()
    tot = {"adc_converts": 100, "no_spec_converts": 400,
           "spec_failures": 5, "spec_attempts": 100,
           "recovery_saturations": 2, "cycles": 64, "macs": 4096}
    obs.record_pim_totals(r, tot, 4, adc_bits=8, engine="e")
    d = obs.record_pim_totals(r, tot, 4, adc_bits=8, engine="e")
    assert d["adc_converts_per_token"] == pytest.approx(200 / 8)
    assert d["spec_failure_rate"] == pytest.approx(10 / 200)
    assert d["saturations_per_token"] == pytest.approx(4 / 8)
    energy = en.pim_work_energy_pj(
        {k: 2 * v for k, v in tot.items()}, 8)
    assert d["pj_per_token"] == pytest.approx(energy["total_pj"] / 8)
    assert d["adc_pj_per_token"] == pytest.approx(energy["e_adc_pj"] / 8)
    assert r.gauge("repro_pim_pj_per_token", "", ("engine",)).get(
        engine="e") == pytest.approx(energy["total_pj"] / 8)
    assert r.counter("repro_pim_adc_converts_total", "",
                     ("engine",)).get(engine="e") == 200


def test_pim_work_energy_pj_components():
    tot = {"adc_converts": 10, "macs": 1000}
    e = en.pim_work_energy_pj(tot, 8)
    assert e["e_adc_pj"] == pytest.approx(
        10 * en.adc_energy_per_convert(8))
    assert e["e_xbar_pj"] == pytest.approx(
        1000 * en.E_CELL_MAX * en.AVG_INPUT_DENSITY
        * en.AVG_WEIGHT_DENSITY["center"])
    assert e["total_pj"] == pytest.approx(
        e["e_adc_pj"] + e["e_digital_pj"] + e["e_xbar_pj"])
    assert en.pim_work_energy_pj({}, 8)["total_pj"] == 0.0


def test_serve_pim_counters_match_collected_stats():
    """End-to-end: the telemetry counters an exact+speculation serve run
    accumulates equal the SpeculationStats totals of a manual
    ``with_pim_stats``-wrapped decode replay of the same request, and
    the pJ/token gauge equals the energy model on those totals."""
    cfg, params = setup()
    cfg = dataclasses.replace(cfg, pim_mode="exact", pim_speculation=True,
                              pim_adc_bits=7)
    prompt = np.asarray(jax.random.randint(
        jax.random.key(1), (4,), 0, cfg.vocab_size), np.int32)
    plans, _ = pim.prepare_pim_params(params, cfg, prompt[None, :])
    steps, max_len = 3, 16

    tel = ServeTelemetry(engine="serve")
    eng = ContinuousServeEngine(cfg, params, n_slots=1, max_len=max_len,
                                plans=plans, telemetry=tel)
    assert tel.wants_pim_stats(cfg) and eng._collect_pim
    outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=steps)])
    assert len(outs[0].tokens) == steps

    # manual replay: whole-prompt prefill (bit-identical to the engine's
    # chunked prefill), then the same wrapped decode jit the engine uses
    step_j = jax.jit(L.with_pim_stats(
        lambda p, pl, st, tok: T.decode_step(p, cfg, st, tok, plans=pl)))
    logits, state = jax.jit(
        lambda p, pl, toks: T.prefill(p, cfg, toks, max_len=max_len,
                                      plans=pl))(params, plans,
                                                 prompt[None, :])
    tok = np.argmax(np.asarray(logits[:, -1, :]), -1)[:, None]
    want = dict.fromkeys(L.PIM_STAT_KEYS, 0)
    replay = [int(tok[0, 0])]
    for _ in range(steps - 1):        # first token came from prefill
        logits, state, tot = step_j(params, plans, state,
                                    tok.astype(np.int32))
        tok = np.argmax(np.asarray(logits[:, -1, :]), -1)[:, None]
        replay.append(int(tok[0, 0]))
        for k in want:
            want[k] += int(tot[k])
    np.testing.assert_array_equal(np.asarray(replay, np.int32),
                                  outs[0].tokens)

    r, lab = tel.registry, {"engine": "serve"}
    for k in L.PIM_STAT_KEYS:
        got = r.counter(f"repro_pim_{k}_total", "", ("engine",)).get(**lab)
        assert got == want[k], (k, got, want[k])
    assert want["spec_attempts"] > 0
    n_tok = r.counter("repro_pim_decode_tokens_total", "",
                      ("engine",)).get(**lab)
    assert n_tok == steps - 1
    energy = en.pim_work_energy_pj(want, cfg.pim_adc_bits)
    assert r.gauge("repro_pim_pj_per_token", "", ("engine",)).get(
        **lab) == pytest.approx(energy["total_pj"] / n_tok)
    assert r.gauge("repro_pim_adc_converts_per_token", "",
                   ("engine",)).get(**lab) == pytest.approx(
        want["adc_converts"] / n_tok)


def test_null_telemetry_collects_nothing():
    assert not NULL_TELEMETRY.enabled
    NULL_TELEMETRY.on_submit(0)
    NULL_TELEMETRY.on_token(0)
    NULL_TELEMETRY.on_pim_totals({"adc_converts": 5}, 1)
    with NULL_TELEMETRY.span("x"):
        pass
    assert NULL_TELEMETRY.registry.snapshot() == {}
    assert NULL_TELEMETRY.tracer.events() == []
    cfg, _ = setup()
    exact = dataclasses.replace(cfg, pim_mode="exact")
    assert not NULL_TELEMETRY.wants_pim_stats(exact)


# -------------------------------------------------- benchmark recorder
def test_benchmark_record_compare_rules():
    from benchmarks.run import _TIMING_KEY, _compare
    base = {"a": 1, "ratio": 1.0, "wall_s": 3.0, "nested": {"ok": True},
            "lat_seconds": {"count": 9}, "tok_per_s_decode": 1.0,
            "tags": ["x", "y"]}
    new = {"a": 1, "ratio": 1.05, "wall_s": 99.0, "nested": {"ok": True},
           "lat_seconds": {"count": 0}, "tok_per_s_decode": 9.0,
           "tags": ["x", "y"]}
    problems: list = []
    _compare(base, new, "r", problems, rtol=0.1)
    assert problems == []                 # timings pruned, floats in rtol
    _compare(base, {**new, "ratio": 1.5}, "r", problems, rtol=0.1)
    assert any("ratio" in p for p in problems)
    problems = []
    _compare({"flag": True}, {"flag": 1}, "r", problems, rtol=0.1)
    assert problems                       # bools never coerce to ints
    problems = []
    _compare({"a": 1, "b": 2}, {"a": 1}, "r", problems, rtol=0.1)
    assert any("missing" in p for p in problems)
    assert _TIMING_KEY.search("repro_serve_ttft_seconds")
    assert not _TIMING_KEY.search("budget_tokens")


def test_write_metrics_document(tmp_path):
    r = MetricsRegistry()
    r.counter("c_total", "c").inc(2)
    path = tmp_path / "m.json"
    doc = obs.write_metrics(r, str(path), config={"arch": "x"})
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(doc))
    assert loaded["config"] == {"arch": "x"}
    assert "c_total 2" in loaded["prometheus"]
    assert loaded["metrics"]["c_total"]["series"][0]["value"] == 2
