"""repro.dist coverage beyond the seed tests: shard() no-op behaviour,
ragged fit_spec_to_shape, sanitize_shardings validation, and rule-set
precedence (serve vs default vs multipod)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import shard
from repro.dist import sharding as dsh


class TestShardNoOp:
    def test_identity_without_mesh(self):
        x = jnp.ones((4, 8))
        assert shard(x, "batch", "tp") is x

    def test_identity_with_empty_rules(self, small_mesh):
        x = jnp.ones((4, 8))
        with dsh.axis_rules(()), small_mesh:
            assert shard(x, "batch", "tp") is x

    def test_rank_mismatch_raises(self, small_mesh):
        with dsh.axis_rules(dsh.DEFAULT_RULES), small_mesh:
            with pytest.raises(ValueError, match="rank"):
                shard(jnp.ones((4, 8)), "batch", "seq", "tp")


class TestShardApplies:
    def test_constraint_under_mesh(self, small_mesh):
        with dsh.axis_rules(dsh.DEFAULT_RULES), small_mesh:
            out = jax.jit(lambda a: shard(a, "batch", "tp"))(jnp.ones((4, 8)))
        assert out.sharding.spec == P("data", "model")

    def test_indivisible_dim_degrades_to_replication(self, small_mesh):
        # batch dim 3 does not divide data=2: constraint drops that axis
        # instead of failing to compile
        with dsh.axis_rules(dsh.DEFAULT_RULES), small_mesh:
            out = jax.jit(lambda a: shard(a, "batch", "tp"))(jnp.ones((3, 8)))
        assert out.sharding.spec == P(None, "model")


class TestFitSpecRagged:
    def test_both_axes_indivisible(self, abstract_mesh):
        spec = dsh.fit_spec_to_shape(P("data", "model"), (3, 5), abstract_mesh)
        assert spec == P(None, None)

    def test_tuple_prefix_kept(self, abstract_mesh):
        # 10 % (2*2) != 0 but 10 % 2 == 0: keep the ("data",) prefix
        spec = dsh.fit_spec_to_shape(P(("data", "model"), None), (10, 7),
                                     abstract_mesh)
        assert spec == P("data", None)

    def test_rank_pad_not_required(self, abstract_mesh):
        # shorter spec than shape is fine (trailing dims replicated)
        spec = dsh.fit_spec_to_shape(P("data"), (4, 9, 2), abstract_mesh)
        assert spec == P("data")

    def test_overlong_spec_rejected(self, abstract_mesh):
        with pytest.raises(ValueError, match="rank"):
            dsh.fit_spec_to_shape(P("data", "model"), (4,), abstract_mesh)

    def test_zero_dim_replicates(self, abstract_mesh):
        # 0 % n == 0, but a dim of 1 cannot be split
        spec = dsh.fit_spec_to_shape(P("data", "model"), (1, 4), abstract_mesh)
        assert spec == P(None, "model")


class TestSanitizeShardings:
    def _sh(self, mesh, *axes):
        return NamedSharding(mesh, P(*axes))

    def test_refits_indivisible(self, small_mesh):
        sh = {"a": self._sh(small_mesh, "data", "model")}
        abstract = {"a": jax.ShapeDtypeStruct((3, 8), jnp.float32)}
        out = dsh.sanitize_shardings(sh, abstract)
        assert out["a"].spec == P(None, "model")

    def test_mismatched_structure_rejected(self, small_mesh):
        sh = {"a": self._sh(small_mesh, "data")}
        abstract = {"a": jax.ShapeDtypeStruct((4,), jnp.float32),
                    "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
        with pytest.raises((ValueError, KeyError)):
            dsh.sanitize_shardings(sh, abstract)

    def test_overlong_spec_rejected(self, small_mesh):
        sh = {"a": self._sh(small_mesh, "data", "model")}
        abstract = {"a": jax.ShapeDtypeStruct((8,), jnp.float32)}
        with pytest.raises(ValueError):
            dsh.sanitize_shardings(sh, abstract)

    def test_non_sharding_leaves_pass_through(self, small_mesh):
        sh = {"a": self._sh(small_mesh, "data"), "n": None}
        abstract = {"a": jax.ShapeDtypeStruct((4,), jnp.float32), "n": None}
        out = dsh.sanitize_shardings(sh, abstract)
        assert out["n"] is None


class TestRulePrecedence:
    def test_default_vs_serve_weights(self, abstract_mesh):
        with dsh.axis_rules(dsh.DEFAULT_RULES):
            assert dsh.spec_for(("fsdp", "tp"), abstract_mesh) == \
                P("data", "model")
        with dsh.axis_rules(dsh.SERVE_RULES):
            assert dsh.spec_for(("fsdp", "tp"), abstract_mesh) == \
                P(None, ("data", "model"))

    def test_innermost_context_wins_and_restores(self, abstract_mesh):
        with dsh.axis_rules(dsh.DEFAULT_RULES):
            with dsh.axis_rules(dsh.SERVE_RULES):
                assert dsh.spec_for(("batch",), abstract_mesh) == P()
            assert dsh.spec_for(("batch",), abstract_mesh) == P("data")

    def test_default_outside_any_context(self, abstract_mesh):
        assert dsh.current_rules() == dsh.DEFAULT_RULES
        assert dsh.spec_for(("batch",), abstract_mesh) == P("data")

    def test_multipod_rules_span_pod_axis(self):
        mesh = jax.sharding.AbstractMesh((2, 2, 2),
                                         ("pod", "data", "model"))
        with dsh.axis_rules(dsh.MULTIPOD_RULES):
            assert dsh.spec_for(("batch", "seq"), mesh) == \
                P(("pod", "data"), "model")
        with dsh.axis_rules(dsh.MULTIPOD_SERVE_RULES):
            # cache: batch over pod x data, seq over model; kv_heads would
            # reuse "data"+"model" and degrades to replication
            assert dsh.spec_for(("cache_batch", "seq", "kv_heads", None),
                                mesh) == P(("pod", "data"), "model")

    def test_multipod_rules_degrade_on_single_pod_mesh(self, abstract_mesh):
        # no "pod" axis on this mesh: the rule's surviving axes still apply
        with dsh.axis_rules(dsh.MULTIPOD_RULES):
            assert dsh.spec_for(("batch",), abstract_mesh) == P("data")

    def test_first_match_wins_for_overrides(self, abstract_mesh):
        rules = (("batch", "model"),) + dsh.DEFAULT_RULES
        with dsh.axis_rules(rules):
            assert dsh.spec_for(("batch",), abstract_mesh) == P("model")
