"""The ``pim_mode`` contract, end-to-end through models + serve.

``prepare_pim_params`` compiles every weight-static projection once
(Algorithm 1); the plan pytree rides the layer scans, and:

- ``fast`` produces *different* (quantized) logits than ``off`` while
  greedy tokens agree on the calibration prompt, and stays within the
  documented dequant tolerance of the ``int8`` ideal-quantized reference;
- ``exact`` equals the ``int8`` reference **bit-exactly** at noise 0 /
  non-saturating ADC (the paper's fidelity contract, now at whole-model
  scope);
- lockstep and continuous engines stay bit-identical under
  ``pim_mode='fast'`` (the plans thread through prefill_chunk /
  decode_step identically).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import pim
from repro.models import transformer as T
from repro.serve import ContinuousServeEngine, Request, ServeEngine

STEPS = 6


def _calib(cfg, b=2, s=12, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (b, s), 0, cfg.vocab_size), np.int32)


@pytest.fixture(scope="module")
def fast_setup():
    cfg = dataclasses.replace(configs.get("yi-6b").reduced(),
                              pim_mode="fast")
    params, _ = T.init_params(cfg, jax.random.key(0))
    # pinned prompt seed: greedy token agreement between the float and the
    # 8b-quantized path is a property of this calibration prompt (random-
    # init logits are nearly flat, so argmax survives quantization only on
    # prompts with a clear margin — seed 3 agrees for 8 feedback steps)
    calib = _calib(cfg, seed=3)
    plans, specs = pim.prepare_pim_params(params, cfg, calib)
    return cfg, params, calib, plans, specs


class TestFastMode:
    def test_logits_quantized_but_greedy_tokens_agree(self, fast_setup):
        """Acceptance: fast produces different (quantized) logits than
        off while greedy decode tokens agree on the calibration prompt."""
        cfg, params, calib, plans, _ = fast_setup
        cfg_off = dataclasses.replace(cfg, pim_mode="off")
        lg_off = T.forward(params, cfg_off, jnp.asarray(calib))
        lg_fast = T.forward(params, cfg, jnp.asarray(calib), plans=plans)
        assert float(jnp.abs(lg_fast - lg_off).max()) > 0
        max_len = calib.shape[1] + STEPS + 1
        eng_off = ServeEngine(cfg_off, params, max_len=max_len)
        eng_fast = ServeEngine(cfg, params, max_len=max_len, plans=plans)
        t_off = eng_off.generate(calib, steps=STEPS).tokens
        t_fast = eng_fast.generate(calib, steps=STEPS).tokens
        np.testing.assert_array_equal(t_off, t_fast)

    def test_fast_within_tolerance_of_int8_reference(self, fast_setup):
        """Documented tolerance: the centered quantizer (fast) vs the
        symmetric per-channel quantizer (int8 reference) differ only by
        combined weight-rounding — a few percent in logit norm on a tiny
        config."""
        cfg, params, calib, plans, _ = fast_setup
        lg_fast = T.forward(params, cfg, jnp.asarray(calib), plans=plans)
        cfg_i8 = dataclasses.replace(cfg, pim_mode="int8")
        lg_i8 = T.forward(params, cfg_i8, jnp.asarray(calib), plans=plans)
        rel = float(jnp.linalg.norm(lg_fast - lg_i8)
                    / jnp.linalg.norm(lg_i8))
        assert rel < 0.05

    def test_engines_require_plans(self, fast_setup):
        cfg, params, *_ = fast_setup
        with pytest.raises(ValueError, match="prepare_pim_params"):
            ServeEngine(cfg, params, max_len=16)
        with pytest.raises(ValueError, match="prepare_pim_params"):
            ContinuousServeEngine(cfg, params, max_len=16)

    def test_plan_specs_mirror_plans(self, fast_setup, abstract_mesh):
        """Sharding contract: the spec tree mirrors the plan tree, the
        int8 offset planes keep the float weight's logical axes, and every
        leaf resolves under SERVE_RULES."""
        import jax.sharding as jsh

        from repro.dist import sharding as dist_sharding
        cfg, params, _, plans, specs = fast_setup
        assert (jax.tree.structure(jax.tree.map(lambda _: 0, plans))
                == jax.tree.structure(
                    jax.tree.map(lambda _: 0, specs,
                                 is_leaf=lambda x: isinstance(x, tuple))))
        pspecs = T.param_specs(cfg)
        attn_idx = cfg.block_pattern.index("attn")
        w_spec = tuple(pspecs["blocks"][attn_idx]["core"]["wq"])
        leaf = specs["blocks"][attn_idx]["core"]["wq"]
        assert leaf["w_off"] == w_spec
        assert leaf["centers"] == (w_spec[0], w_spec[-1])
        for name, spec in leaf.items():
            arr = plans["blocks"][attn_idx]["core"]["wq"][name]
            assert len(spec) == arr.ndim, name
        with dist_sharding.axis_rules(dist_sharding.SERVE_RULES):
            resolved = jax.tree.map(
                lambda s: dist_sharding.spec_for(s, abstract_mesh),
                specs, is_leaf=lambda x: isinstance(x, tuple))
        for p in jax.tree.leaves(
                resolved, is_leaf=lambda x: isinstance(x, jsh.PartitionSpec)):
            assert isinstance(p, jsh.PartitionSpec)

    def test_lockstep_vs_continuous_bit_identical(self, fast_setup):
        cfg, params, calib, plans, _ = fast_setup
        max_len = calib.shape[1] + STEPS + 1
        lock = ServeEngine(cfg, params, max_len=max_len, plans=plans)
        want = lock.generate(calib, steps=STEPS).tokens
        cont = ContinuousServeEngine(cfg, params, n_slots=2,
                                     max_len=max_len, prefill_chunk=5,
                                     plans=plans)
        outs = cont.run([Request(uid=i, prompt=calib[i],
                                 max_new_tokens=STEPS)
                         for i in range(calib.shape[0])])
        for i, o in enumerate(outs):
            np.testing.assert_array_equal(o.tokens, want[i])


class TestExactMode:
    def test_exact_equals_int8_reference_bit_exact(self):
        """At noise 0 with a non-saturating (24b) ADC the full datapath
        simulation — Center+Offset, sliced crossbars, speculation, signed
        two-pass — reproduces the ideal 8b-quantized model bit-for-bit,
        layer after layer through greedy prefill+decode."""
        cfg = configs.get("yi-6b").reduced(
            n_layers=1, d_model=32, d_ff=48, vocab_size=64, n_heads=2,
            n_kv_heads=1, head_dim=16)
        cfg = dataclasses.replace(cfg, pim_mode="exact")
        params, _ = T.init_params(cfg, jax.random.key(0))
        calib = _calib(cfg, b=2, s=8, seed=2)
        plans, _ = pim.prepare_pim_params(params, cfg, calib)
        cfg_i8 = dataclasses.replace(cfg, pim_mode="int8")

        lg_e = T.forward(params, cfg, jnp.asarray(calib), plans=plans)
        lg_i = T.forward(params, cfg_i8, jnp.asarray(calib), plans=plans)
        np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_i))

        lg_e, st_e = T.prefill(params, cfg, jnp.asarray(calib),
                               max_len=12, plans=plans)
        lg_i, st_i = T.prefill(params, cfg_i8, jnp.asarray(calib),
                               max_len=12, plans=plans)
        np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_i))
        tok = jnp.argmax(lg_e[:, -1:], -1)
        de, _ = T.decode_step(params, cfg, st_e, tok, plans=plans)
        di, _ = T.decode_step(params, cfg_i8, st_i, tok, plans=plans)
        np.testing.assert_array_equal(np.asarray(de), np.asarray(di))


class TestArchCoverage:
    """The dispatcher reaches every projection family: GQA attention,
    MoE experts, and mamba in/x/out (hybrid pattern)."""

    @pytest.mark.parametrize("arch", ["phi3.5-moe-42b",
                                      "jamba-1.5-large-398b"])
    def test_fast_forward_close_to_float(self, arch):
        cfg = dataclasses.replace(configs.get(arch).reduced(),
                                  pim_mode="fast")
        params, _ = T.init_params(cfg, jax.random.key(0))
        calib = _calib(cfg, b=1, s=8, seed=3)
        plans, _ = pim.prepare_pim_params(params, cfg, calib)
        cfg_off = dataclasses.replace(cfg, pim_mode="off")
        lg_off = T.forward(params, cfg_off, jnp.asarray(calib))
        lg_fast = T.forward(params, cfg, jnp.asarray(calib), plans=plans)
        assert float(jnp.abs(lg_fast - lg_off).max()) > 0
        rel = float(jnp.linalg.norm(lg_fast - lg_off)
                    / jnp.linalg.norm(lg_off))
        assert rel < 0.2
