"""Differential harness for the fused speculation/recovery kernel.

``repro.kernels.fused_spec_crossbar`` runs the whole Dynamic Input
Slicing pass (paper §4.3) in one launch: spec-slice cropping, slice-plane
matmuls, per-segment signed ADC clamp, saturation-as-failure detection,
in-kernel 1b recovery converts, select, shift+add, center term. These
tests lock it to three independent ground truths:

  1. the ``core.speculation.forward`` Python loop (``backend='python'``)
     — the datapath the paper's convert-economy numbers come from;
  2. the pure-jnp oracle ``kernels.ref.fused_spec_crossbar`` (the
     registry's XLA backend);
  3. a standalone numpy loop written here (so a shared bug in the kernel
     *and* ``ref`` cannot hide).

Sweeps cover random spec x weight slicings, ADC bits 4..8, ragged
``valid`` masks from adaptive per-site plans, both interpret and XLA
backends, jit, and end-to-end greedy decode — everything bit-exact,
including every ``SpeculationStats`` work counter. The satellite fixes
(int32-overflowing counters, the silent-noiseless hazard, the negative-
pad shape mismatch) get their regression tests here too.
"""

import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import slicing as sl
from repro.core import speculation as spec
from repro.kernels import ops

BACKENDS = ("interpret", "xla")

STAT_FIELDS = ("adc_converts", "no_spec_converts", "spec_failures",
               "spec_attempts", "recovery_saturations", "cycles", "macs")


def _mk_layer(rng, rows, cols, B, weight_slicing, mode="center"):
    w_u = rng.integers(0, 256, (rows, cols)).astype(np.int64)
    enc = co.encode(w_u, weight_slicing, mode=mode)
    x = jnp.asarray(rng.integers(0, 256, (B, rows)))
    return w_u, enc, x


def _stat_ints(s: spec.SpeculationStats) -> tuple[int, ...]:
    return tuple(int(getattr(s, f)) for f in STAT_FIELDS)


def _np_spec(x, planes, shifts, centers, spec_slicing, lo, hi):
    """Independent numpy oracle: the full speculate/recover datapath as
    plain loops (psum, failures, recovery converts, recovery sats)."""
    x = np.asarray(x, np.int64)
    planes = np.asarray(planes, np.int64)  # (n_j, n_seg, R, C)
    centers = np.asarray(centers, np.int64)
    n_j, n_seg, R, C = planes.shape
    B = x.shape[0]
    xp = np.zeros((B, n_seg * R), np.int64)
    xp[:, :x.shape[1]] = x
    xs = xp.reshape(B, n_seg, R)
    psum = np.einsum("bsr,sc->bc", xs, centers)
    failures = rec_converts = rec_sats = 0
    for (hi_b, li) in sl.slice_bounds(spec_slicing, sl.INPUT_BITS):
        width = hi_b - li + 1
        x_i = (xs >> li) & ((1 << width) - 1)
        for j in range(n_j):
            cs = np.einsum("bsr,src->bsc", x_i, planes[j])
            sv = np.clip(cs, lo, hi)
            ssat = (sv == lo) | (sv == hi)
            rec = np.zeros_like(sv)
            for b in range(width):
                xb = (xs >> (li + b)) & 1
                rcs = np.einsum("bsr,src->bsc", xb, planes[j])
                rv = np.clip(rcs, lo, hi)
                rsat = (rv == lo) | (rv == hi)
                rec = rec + (rv << b)
                rec_sats += int((rsat & ssat).sum())
            value = np.where(ssat, rec, sv)
            psum = psum + value.sum(axis=1) * (1 << (li + int(shifts[j])))
            failures += int(ssat.sum())
            rec_converts += width * int(ssat.sum())
    return psum, failures, rec_converts, rec_sats


class TestSpecDifferential:
    """Hypothesis sweep: random shapes x random spec/weight slicings x
    ADC bits 4..8, fused (both backends) vs the Python loop and the
    numpy oracle — psum and every stats field bit-identical."""

    @hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(4, 8))
    @hypothesis.settings(max_examples=6, deadline=None)
    def test_vs_python_loop_and_numpy(self, seed, adc_bits):
        rng = np.random.default_rng(seed)
        all_slicings = sl.enumerate_slicings()
        w_slicing = all_slicings[int(rng.integers(0, len(all_slicings)))]
        spec_slicing = all_slicings[int(rng.integers(0, len(all_slicings)))]
        rows = int(rng.integers(1, 650))
        cols = int(rng.integers(1, 12))
        B = int(rng.integers(1, 4))
        _, enc, x = _mk_layer(rng, rows, cols, B, w_slicing)
        adc = adc_lib.ADCConfig(bits=adc_bits, signed=True)

        want, st_py = spec.forward(x, enc, spec_slicing, adc,
                                   backend="python")
        np_psum, np_fail, np_rconv, np_rsat = _np_spec(
            x, enc.planes, enc.shifts, enc.centers, spec_slicing,
            adc.lo, adc.hi)
        np.testing.assert_array_equal(np.asarray(want, np.int64), np_psum)
        assert int(st_py.spec_failures) == np_fail
        assert int(st_py.adc_converts) == st_py.spec_attempts + np_rconv
        assert int(st_py.recovery_saturations) == np_rsat
        for backend in BACKENDS:
            got, st_f = spec.forward(x, enc, spec_slicing, adc,
                                     backend=backend)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            assert _stat_ints(st_f) == _stat_ints(st_py)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("rows,cols,B,w_slicing,spec_slicing", [
        (1, 1, 1, (4, 4), (8,)),             # minimal + non-narrow spec
        (513, 3, 1, (4, 2, 2), (4, 2, 2)),   # ragged second segment
        (512, 130, 2, (1,) * 8, (4, 4)),     # C off the 128 tile, max n_j
        (300, 7, 1, (2, 2, 2, 2), (2, 2, 2, 2)),  # everything off-tile
    ])
    def test_edge_shapes(self, rows, cols, B, w_slicing, spec_slicing,
                         backend):
        rng = np.random.default_rng(rows * 31 + cols * 7 + B)
        _, enc, x = _mk_layer(rng, rows, cols, B, w_slicing)
        want, st_py = spec.forward(x, enc, spec_slicing, backend="python")
        got, st_f = spec.forward(x, enc, spec_slicing, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert _stat_ints(st_f) == _stat_ints(st_py)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_unsigned_adc_zero_on_lo_bound(self, backend):
        """ISAAC-style unsigned window: 0 sits on the lo bound, so even
        all-zero column sums count as saturated/failed — both paths must
        agree on that (including zero recovery sums re-saturating)."""
        rng = np.random.default_rng(9)
        w_u = rng.integers(0, 256, (256, 8)).astype(np.int64)
        enc = co.encode(w_u, (4, 4), mode="unsigned")
        x = jnp.asarray(rng.integers(0, 256, (3, 256)))
        want, st_py = spec.forward(x, enc, (4, 2, 2), adc_lib.ISAAC_ADC,
                                   backend="python")
        got, st_f = spec.forward(x, enc, (4, 2, 2), adc_lib.ISAAC_ADC,
                                 backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert _stat_ints(st_f) == _stat_ints(st_py)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_saturations_actually_exercised(self, backend):
        """The differential is vacuous if nothing ever fails: wide
        weights + a narrow ADC must produce failures AND recovery
        saturations, and the kernel must still match the loop."""
        rng = np.random.default_rng(13)
        w_u = np.clip(rng.normal(128, 70, (500, 10)), 0, 255).astype(np.int64)
        enc = co.encode(w_u, (4, 2, 2))
        x = jnp.asarray(rng.integers(0, 256, (3, 500)))
        adc = adc_lib.ADCConfig(bits=5, signed=True)
        want, st_py = spec.forward(x, enc, (4, 2, 2), adc, backend="python")
        got, st_f = spec.forward(x, enc, (4, 2, 2), adc, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert _stat_ints(st_f) == _stat_ints(st_py)
        assert int(st_f.spec_failures) > 0
        assert int(st_f.recovery_saturations) > 0
        assert int(st_f.adc_converts) > st_f.spec_attempts


class TestRaggedValid:
    """Adaptive per-site plans pad the weight-slice axis: padded planes
    (zeroed + ``valid`` mask + garbage padded shifts) must be inert on
    every backend — same psum, same failure/saturation counts."""

    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    @hypothesis.settings(max_examples=4, deadline=None)
    def test_padded_planes_inert(self, seed):
        rng = np.random.default_rng(seed)
        all_slicings = sl.enumerate_slicings()
        w_slicing = all_slicings[int(rng.integers(0, len(all_slicings)))]
        rows = int(rng.integers(1, 500))
        cols = int(rng.integers(1, 10))
        _, enc, x = _mk_layer(rng, rows, cols, 2, w_slicing)
        n_s = enc.n_slices
        n_pad = int(rng.integers(1, 4))
        padded_planes = jnp.pad(jnp.asarray(enc.planes),
                                ((0, n_pad), (0, 0), (0, 0), (0, 0)))
        pad_shifts = rng.integers(0, 8, n_pad)
        shifts = jnp.asarray(list(enc.shifts) + list(pad_shifts), jnp.int32)
        valid = jnp.asarray([True] * n_s + [False] * n_pad)

        want, wf, wr = ops.fused_spec_crossbar_forward(
            x, jnp.asarray(enc.planes), jnp.asarray(enc.shifts, jnp.int32),
            jnp.asarray(enc.centers), spec_slicing=(4, 2, 2),
            adc_lo=-64, adc_hi=63, backend="xla")
        for backend in BACKENDS:
            got, gf, gr = ops.fused_spec_crossbar_forward(
                x, padded_planes, shifts, jnp.asarray(enc.centers),
                spec_slicing=(4, 2, 2), adc_lo=-64, adc_hi=63,
                valid=valid, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            np.testing.assert_array_equal(np.asarray(gf), np.asarray(wf))
            assert int(gr) == int(wr)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_speculation_forward_valid(self, backend):
        """``spec.forward(valid=...)`` on a padded encoding: fused and
        Python paths agree on psum AND every stats field, and the psum
        equals the unpadded encoding's."""
        rng = np.random.default_rng(23)
        _, enc, x = _mk_layer(rng, 300, 6, 2, (4, 2, 2))
        padded = dataclasses.replace(
            enc,
            planes=np.pad(enc.planes, ((0, 2), (0, 0), (0, 0), (0, 0))),
            shifts=jnp.asarray(list(enc.shifts) + [5, 3], jnp.int32),
            slicing=None)
        valid = jnp.asarray([True] * enc.n_slices + [False, False])
        want, _ = spec.forward(x, enc, backend="python")
        got_py, st_py = spec.forward(x, padded, valid=valid,
                                     backend="python")
        got_f, st_f = spec.forward(x, padded, valid=valid, backend=backend)
        np.testing.assert_array_equal(np.asarray(got_py), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want))
        assert _stat_ints(st_f) == _stat_ints(st_py)


class TestUnderJit:
    """The fused op must trace cleanly inside jit (the models call it
    from scanned/jitted decode steps) with bit-identical results."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_spec_forward_under_jit(self, backend):
        rng = np.random.default_rng(33)
        _, enc, x = _mk_layer(rng, 200, 8, 2, (4, 2, 2))

        def f(xi):
            psum, s = spec.forward(xi, enc, backend=backend)
            return (psum, s.adc_converts, s.spec_failures,
                    s.recovery_saturations)

        eager = f(x)
        jitted = jax.jit(f)(x)
        for e, j in zip(eager, jitted):
            np.testing.assert_array_equal(np.asarray(e), np.asarray(j))

    def test_registered_backends(self):
        assert set(ops.backends("fused_spec_crossbar")) == \
            {"xla", "interpret", "pallas-tpu"}


class TestWorkCounterScale:
    """Satellite: production batch x column x slice products overflow
    int32 (the historical counter dtype). Shape-static counters are now
    exact Python ints at any scale; data-dependent accumulators use
    ``crossbar.work_dtype()`` (int64 under ``jax_enable_x64``)."""

    def test_work_dtype_tracks_x64(self):
        assert xbar.work_dtype() == jnp.int32  # suite default: no x64
        try:
            jax.config.update("jax_enable_x64", True)
            assert xbar.work_dtype() == jnp.int64
        finally:
            jax.config.update("jax_enable_x64", False)

    def test_crossbar_counters_beyond_int32(self):
        """eval_shape traces the fused crossbar path at a batch size
        whose convert count exceeds 2^31 — the counters must come back
        as exact (un-wrapped) Python ints."""
        rng = np.random.default_rng(5)
        _, enc, _ = _mk_layer(rng, 128, 8, 1, (4, 2, 2))
        B = 1 << 25  # B * n_seg * cols * 8 slices * 3 planes = 6.4e9
        box = {}

        def f(xi):
            psum, s = xbar.forward(xi, enc, (1,) * 8, backend="xla")
            box["st"] = s
            return psum

        jax.eval_shape(f, jax.ShapeDtypeStruct((B, 128), jnp.int32))
        s = box["st"]
        expect = B * 1 * 8 * 8 * 3
        assert expect > 2 ** 31
        assert type(s.adc_converts) is int and s.adc_converts == expect
        assert type(s.conversions_possible) is int
        assert s.conversions_possible == expect
        assert type(s.macs) is int and s.macs == B * 128 * 8

    def test_speculation_counters_beyond_int32(self):
        """Same at the speculation layer: the static counters survive
        any scale, and with x64 on, the data-dependent ones (converts,
        failures, recovery sats) accumulate in int64."""
        rng = np.random.default_rng(6)
        _, enc, _ = _mk_layer(rng, 128, 8, 1, (4, 2, 2))
        B = 1 << 25
        box = {}

        def f(xi):
            psum, s = spec.forward(xi, enc, (4, 2, 2), backend="python")
            box["st"] = s
            return psum

        try:
            jax.config.update("jax_enable_x64", True)
            jax.eval_shape(f, jax.ShapeDtypeStruct((B, 128), jnp.int32))
        finally:
            jax.config.update("jax_enable_x64", False)
        s = box["st"]
        assert type(s.spec_attempts) is int and s.spec_attempts > 2 ** 31
        assert type(s.no_spec_converts) is int
        assert s.no_spec_converts == B * 8 * 8 * 3
        assert type(s.macs) is int and s.macs == B * 128 * 8
        assert s.adc_converts.dtype == jnp.int64
        assert s.spec_failures.dtype == jnp.int64
        assert s.recovery_saturations.dtype == jnp.int64

    def test_small_scale_counts_still_exact(self):
        """The promotion changed dtypes, not values: pinned-shape counts
        match the closed-form arithmetic."""
        rng = np.random.default_rng(7)
        _, enc, x = _mk_layer(rng, 96, 6, 3, (4, 2, 2))
        _, s = spec.forward(x, enc, (4, 2, 2), backend="python")
        assert s.spec_attempts == 3 * 1 * 6 * 3 * 3
        assert s.no_spec_converts == 3 * 1 * 6 * 8 * 3
        assert s.cycles == 3 + 8
        assert s.macs == 3 * 96 * 6
        assert int(s.adc_converts) >= s.spec_attempts


class TestNoiseGuard:
    """Satellite: requesting noise without a key used to silently run
    noiseless — now it refuses loudly in both entry points."""

    def test_crossbar_raises_without_key(self):
        rng = np.random.default_rng(11)
        _, enc, x = _mk_layer(rng, 64, 4, 2, (4, 4))
        with pytest.raises(ValueError, match="requires a PRNG key"):
            xbar.forward(x, enc, (4, 4), noise_level=0.05)

    def test_speculation_raises_without_key(self):
        rng = np.random.default_rng(11)
        _, enc, x = _mk_layer(rng, 64, 4, 2, (4, 4))
        with pytest.raises(ValueError, match="requires a PRNG key"):
            spec.forward(x, enc, noise_level=0.05)

    def test_noise_with_key_runs_the_loop(self):
        """The noisy path still works (it takes the Python loop — the
        per-conversion noise model is stateful) and actually perturbs."""
        rng = np.random.default_rng(12)
        _, enc, x = _mk_layer(rng, 256, 6, 2, (4, 2, 2))
        clean, _ = spec.forward(x, enc, backend="python")
        noisy, s = spec.forward(x, enc, noise_level=0.3,
                                key=jax.random.key(0))
        assert noisy.shape == clean.shape
        assert int(jnp.abs(noisy - clean).max()) > 0
        assert s.cycles == 11

    def test_noise_zero_with_key_is_noiseless(self):
        rng = np.random.default_rng(12)
        _, enc, x = _mk_layer(rng, 128, 4, 2, (4, 4))
        a, _ = spec.forward(x, enc, backend="python")
        b, _ = spec.forward(x, enc, noise_level=0.0, key=jax.random.key(1))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestShapeMismatch:
    """Satellite: a negative pad (inputs wider than the encoding's
    crossbar capacity) used to crash deep inside ``jnp.pad`` — now it
    names the mismatch."""

    def test_segment_inputs_negative_pad(self):
        with pytest.raises(ValueError, match="exceed the crossbar capacity"):
            xbar._segment_inputs(jnp.zeros((2, 600), jnp.int32), 1, 512)

    def test_forward_with_mismatched_encoding(self):
        rng = np.random.default_rng(15)
        _, enc, _ = _mk_layer(rng, 300, 4, 2, (4, 4))  # capacity 512
        x_wide = jnp.asarray(rng.integers(0, 256, (2, 700)))
        with pytest.raises(ValueError, match="exceed the crossbar capacity"):
            xbar.forward(x_wide, enc, (4, 4), backend="python")
        with pytest.raises(ValueError, match="exceed the crossbar capacity"):
            spec.forward(x_wide, enc, backend="python")


class TestEndToEndDecode:
    """The wired dispatch: exact-mode + speculation greedy decode is
    bit-identical between the fused kernel backends and the Python loop,
    through the jitted decode step, with identical collected work
    totals — the contract ``benchmarks/serve_pim.py --speculation``
    reports against."""

    STEPS = 3
    _cache: dict = {}

    def _decode_trace(self, backend):
        if backend in self._cache:
            return self._cache[backend]
        from repro import configs
        from repro.models import layers as L
        from repro.models import pim
        from repro.models import transformer as T
        cfg = dataclasses.replace(
            configs.get("yi-6b").reduced(), pim_mode="exact",
            pim_speculation=True, pim_kernel_backend=backend)
        params, _ = T.init_params(cfg, jax.random.key(0))
        prompts = np.asarray(jax.random.randint(
            jax.random.key(1), (2, 4), 0, cfg.vocab_size))
        plans, _ = pim.prepare_pim_params(params, cfg, prompts)

        def step(p, pl, state, tok):
            with L.collect_pim_stats() as acc:
                logits, st2 = T.decode_step(p, cfg, state, tok, plans=pl)
                totals = L.pim_stats_totals(acc)
            return logits, st2, totals

        step_j = jax.jit(step)
        logits, state = T.prefill(params, cfg, jnp.asarray(prompts),
                                  max_len=4 + self.STEPS + 1, plans=plans)
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
        toks, logit_trace = [np.asarray(tok)], []
        totals = dict.fromkeys(L.PIM_STAT_KEYS, 0)
        for _ in range(self.STEPS):
            logits, state, tot = step_j(params, plans, state, tok)
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
            toks.append(np.asarray(tok))
            logit_trace.append(np.asarray(logits))
            for k in totals:
                totals[k] += int(tot[k])
        self._cache[backend] = (np.concatenate(toks, 1), logit_trace,
                                totals)
        return self._cache[backend]

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_greedy_bit_identity_vs_python(self, backend):
        ref_toks, ref_logits, ref_totals = self._decode_trace("python")
        toks, logits, totals = self._decode_trace(backend)
        np.testing.assert_array_equal(toks, ref_toks)
        for a, b in zip(logits, ref_logits):
            np.testing.assert_array_equal(a, b)
        assert totals == ref_totals
        assert totals["adc_converts"] >= totals["spec_attempts"] > 0
        assert totals["adc_converts"] < totals["no_spec_converts"]
