"""Paged KV cache: model-layer bit-identity vs the contiguous cache,
engine bit-identity vs lockstep (attention + recurrent archs), prefix
sharing refcounts, admission under memory pressure, block-granular free,
eviction-by-recompute, and disaggregated prefill/decode mesh slices."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_disaggregated_meshes
from repro.models import transformer as T
from repro.serve import (
    BlockAllocator,
    EngineStats,
    PagedServeEngine,
    Request,
    ServeEngine,
    ServeStats,
)

_CACHE: dict = {}


def setup(arch: str):
    if arch not in _CACHE:
        cfg = configs.get(arch).reduced()
        params, _ = T.init_params(cfg, jax.random.key(0))
        _CACHE[arch] = (cfg, params)
    return _CACHE[arch]


def mixed_requests(cfg, n=5, seed=0, **overrides):
    rng = np.random.default_rng(seed)
    plens = [3, 7, 5, 9, 4, 6, 8][:n]
    steps = [6, 3, 9, 4, 7, 2, 5][:n]
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plens[i]).astype(np.int32),
                    max_new_tokens=steps[i], **overrides)
            for i in range(n)]


def lockstep_refs(cfg, params, reqs, max_len):
    eng = ServeEngine(cfg, params, max_len=max_len)
    return {r.uid: eng.generate(r.prompt[None, :],
                                steps=r.max_new_tokens).tokens[0]
            for r in reqs}


# ----------------------------------------------------------- model layer
@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_paged_prefill_decode_matches_contiguous(kv_dtype):
    """prefill_chunk_paged + block-table decode == whole-prompt prefill +
    contiguous decode, bit-for-bit, with a scrambled (non-identity) block
    table and a garbage-filled pool."""
    cfg, params = setup("yi-6b")
    cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    prompt = np.asarray(jax.random.randint(
        jax.random.key(1), (1, 9), 0, cfg.vocab_size), np.int32)
    # reference: the contiguous cache walked with the SAME chunking —
    # chunked == whole-prompt prefill is already the float contract
    # (prefill_chunk docstring); int8 round-trips per chunk, so the
    # paged/contiguous comparison must share chunk boundaries.
    chunks = [(0, 4), (4, 8), (8, 9)]
    st_ref = T.init_decode_state(cfg, 1, 16)
    for lo, hi in chunks:
        logits_ref, st_ref = T.prefill_chunk(params, cfg, st_ref,
                                             jnp.asarray(prompt[:, lo:hi]))
    layout = T.PagedLayout(n_blocks=7, block_size=4)   # 4 needed of 7
    st = T.init_decode_state(cfg, 1, 16, per_slot_pos=True, paged=layout)
    # garbage in the pool must be masked out by kv_len, never read
    st = {k: (jax.tree.map(lambda a: a + (7 if a.dtype == jnp.int8
                                          else 7.0), v)
              if isinstance(v, dict) else v) for k, v in st.items()}
    table = jnp.asarray([5, 2, 6, 0], jnp.int32)       # scrambled
    for lo, hi in chunks:
        logits, st = T.prefill_chunk_paged(
            params, cfg, st, jnp.asarray(prompt[:, lo:hi]),
            slot=jnp.asarray(0, jnp.int32), table_row=table,
            pos0=jnp.asarray(lo, jnp.int32), paged=layout)
    assert jnp.array_equal(logits_ref[:, -1], logits[:, -1])
    assert st["pos"].tolist() == [9]
    tok = jnp.argmax(logits_ref[:, -1], -1)[:, None].astype(jnp.int32)
    l_ref, _ = T.decode_step(params, cfg, st_ref, tok)
    l_pg, st = T.decode_step(params, cfg, st, tok, block_tables=table[None],
                             paged=layout)
    assert jnp.array_equal(l_ref, l_pg)
    assert st["pos"].tolist() == [10]


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-3b"])
def test_insert_request_paged_matches_contiguous_decode(arch):
    """A contiguous B=1 prefill scattered into pool blocks decodes
    identically to the contiguous batched path (the staged-prefill and
    disaggregated-handoff primitive; recurrent carries ride along)."""
    cfg, params = setup(arch)
    prompt = np.arange(2, 9, dtype=np.int32)[None]
    logits, one = T.prefill(params, cfg, jnp.asarray(prompt), max_len=16)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    ref = T.decode_step(params, cfg, one, tok)[0]
    layout = T.PagedLayout(n_blocks=9, block_size=4)
    st = T.init_decode_state(cfg, 2, 16, per_slot_pos=True, paged=layout)
    table = jnp.asarray([4, 1, 7, 0], jnp.int32)
    st = T.insert_request_paged(st, one, jnp.asarray(1, jnp.int32), table,
                                layout)
    assert st["pos"].tolist() == [0, 7]
    tables = jnp.stack([jnp.full((4,), layout.sentinel, jnp.int32), table])
    toks = jnp.concatenate([jnp.zeros((1, 1), jnp.int32), tok])
    out, _ = T.decode_step(params, cfg, st, toks, block_tables=tables,
                           paged=layout)
    assert jnp.array_equal(out[1:2], ref)


def test_decode_step_paged_arg_validation():
    cfg, params = setup("yi-6b")
    layout = T.PagedLayout(n_blocks=4, block_size=4)
    st = T.init_decode_state(cfg, 1, 16, per_slot_pos=True, paged=layout)
    tok = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="block_tables"):
        T.decode_step(params, cfg, st, tok, paged=layout)
    st_scalar = T.init_decode_state(cfg, 1, 16)
    with pytest.raises(ValueError, match="per_slot_pos"):
        T.decode_step(params, cfg, st_scalar, tok,
                      block_tables=jnp.zeros((1, 4), jnp.int32),
                      paged=layout)


# ---------------------------------------------------------------- engine
@pytest.mark.parametrize("arch", ["yi-6b", "jamba-1.5-large-398b",
                                  "rwkv6-3b"])
def test_paged_engine_matches_lockstep(arch):
    """Greedy paged-engine outputs are bit-identical to solo lockstep runs
    — copy-free in-place prefill for attention-only stacks, staged B=1
    prefill + paged insert for recurrent (mamba/rwkv) stacks."""
    cfg, params = setup(arch)
    reqs = mixed_requests(cfg)
    eng = PagedServeEngine(cfg, params, n_slots=2, max_len=32,
                           prefill_chunk=4, block_size=4)
    attn_only = all(k == "attn" for k in cfg.block_pattern)
    assert eng.staged_prefill == (not attn_only)
    outs = eng.run(reqs)
    refs = lockstep_refs(cfg, params, reqs, 32)
    for o in outs:
        assert np.array_equal(o.tokens, refs[o.uid]), f"uid {o.uid}"
    assert eng.stats.completed == 5
    assert eng.stats.blocks_in_use == 0          # block-granular free
    assert eng.stats.peak_blocks_in_use > 0
    assert len(eng.alloc.free) == eng.alloc.n_blocks


def test_prefix_sharing_refcounts():
    """A live request's full prompt blocks register for sharing; later
    admissions with the same system prompt claim them (refcount, no
    copy), and draining returns the pool with an empty prefix index."""
    cfg, params = setup("yi-6b")
    sys_prompt = np.arange(1, 9, dtype=np.int32)       # 2 full blocks
    rng = np.random.default_rng(2)
    reqs = [Request(uid=u, prompt=np.concatenate(
                [sys_prompt,
                 rng.integers(0, cfg.vocab_size, x).astype(np.int32)]),
                max_new_tokens=s)
            for u, (x, s) in enumerate([(3, 6), (5, 4), (2, 5)])]
    eng = PagedServeEngine(cfg, params, n_slots=3, max_len=32,
                           prefill_chunk=4, block_size=4)
    assert eng.prefix_sharing
    eng.submit(reqs[0])
    while not eng.alloc.prefix_index:                  # until uid 0 is live
        eng.step()
    shared = list(eng.alloc.prefix_index.values())
    assert len(shared) == 2
    eng.submit(reqs[1])
    eng.submit(reqs[2])
    eng.step()                                         # both admitted
    assert [int(eng.alloc.refcount[b]) for b in shared] == [3, 3]
    assert eng.stats.prefix_block_hits == 4            # 2 blocks x 2 reqs
    outs = eng.run([])
    refs = lockstep_refs(cfg, params, reqs, 32)
    for o in outs:                                     # sharing is exact
        assert np.array_equal(o.tokens, refs[o.uid]), f"uid {o.uid}"
    assert eng.stats.blocks_in_use == 0
    assert not eng.alloc.prefix_index                  # unregistered on free


def test_admission_waits_under_memory_pressure():
    """With a pool too small for both prompts the head of the queue waits
    (strict FIFO — admission order is arrival order), is admitted once
    blocks free, and every output still matches lockstep."""
    cfg, params = setup("yi-6b")
    rng = np.random.default_rng(3)
    reqs = [Request(uid=u,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        9).astype(np.int32),
                    max_new_tokens=3) for u in range(3)]
    eng = PagedServeEngine(cfg, params, n_slots=3, max_len=16,
                           prefill_chunk=16, block_size=4, n_blocks=4,
                           prefix_sharing=False)      # one request at a time
    for r in reqs:
        eng.submit(r)
    admitted_order = []
    outs = []
    while eng.has_work:
        before = set(eng.active_uids)
        outs.extend(eng.step())
        admitted_order += [u for u in eng.active_uids if u not in before]
    assert admitted_order == [0, 1, 2]                 # FIFO held
    assert eng.stats.admission_waits > 0
    assert eng.stats.evictions == 0                    # waiters, not victims
    refs = lockstep_refs(cfg, params, reqs, 16)
    for o in outs:
        assert np.array_equal(o.tokens, refs[o.uid])
    assert eng.stats.blocks_in_use == 0


def test_blocks_free_on_stop_token():
    """A stop token frees the slot's blocks the same iteration — memory
    tracks actual generated length, not max_new_tokens."""
    cfg, params = setup("yi-6b")
    [req] = mixed_requests(cfg, n=1)
    eng = PagedServeEngine(cfg, params, n_slots=1, max_len=32,
                           prefill_chunk=8, block_size=4)
    [full] = eng.run([req])
    stop = int(full.tokens[2])
    eng2 = PagedServeEngine(cfg, params, n_slots=1, max_len=32,
                            prefill_chunk=8, block_size=4)
    eng2.submit(Request(uid=0, prompt=req.prompt,
                        max_new_tokens=req.max_new_tokens,
                        stop_tokens=(stop,)))
    outs = []
    while eng2.has_work:
        done = eng2.step()
        if done:
            assert done[0].finish_reason == "stop"
            assert eng2.stats.blocks_in_use == 0       # freed this iteration
            outs += done
    first = int(np.argmax(full.tokens == stop))
    assert np.array_equal(outs[0].tokens, full.tokens[:first + 1])
    assert eng2.stats.peak_blocks_in_use >= 1
    assert len(eng2.alloc.free) == eng2.alloc.n_blocks


def test_eviction_recompute_is_bit_identical():
    """Pool exhaustion mid-decode preempts the youngest request (blocks
    freed, requeued at the front); its recompute replays identical greedy
    tokens, so eviction is invisible in the outputs."""
    cfg, params = setup("yi-6b")
    rng = np.random.default_rng(4)
    reqs = [Request(uid=0, prompt=rng.integers(0, cfg.vocab_size,
                                               9).astype(np.int32),
                    max_new_tokens=14),
            Request(uid=1, prompt=rng.integers(0, cfg.vocab_size,
                                               9).astype(np.int32),
                    max_new_tokens=8)]
    eng = PagedServeEngine(cfg, params, n_slots=2, max_len=24,
                           prefill_chunk=16, block_size=4, n_blocks=6,
                           prefix_sharing=False)
    outs = eng.run(reqs)
    assert eng.stats.evictions >= 1
    refs = lockstep_refs(cfg, params, reqs, 24)
    for o in outs:
        assert np.array_equal(o.tokens, refs[o.uid]), f"uid {o.uid}"
    assert eng.stats.blocks_in_use == 0


def test_engine_validation():
    cfg, params = setup("yi-6b")
    with pytest.raises(ValueError, match="divide"):
        PagedServeEngine(cfg, params, max_len=30, block_size=4)
    with pytest.raises(ValueError, match="never fit"):
        PagedServeEngine(cfg, params, max_len=32, block_size=4, n_blocks=4)
    with pytest.raises(ValueError, match="both prefill_mesh"):
        PagedServeEngine(cfg, params, max_len=32, block_size=4,
                         decode_mesh=object())


# ---------------------------------------------------------- disaggregated
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 (fake) devices for two (1,2,2) slices")
def test_disaggregated_prefill_decode_slices():
    """Prefill on one (pod, data, model) slice, decode on a disjoint one,
    params/plans replicated to both, finished blocks handed over — still
    bit-identical to lockstep, with decode state on the decode slice."""
    cfg, params = setup("yi-6b")
    pm, dm = make_disaggregated_meshes()
    assert not (set(pm.devices.flat) & set(dm.devices.flat))
    reqs = mixed_requests(cfg, n=3)
    eng = PagedServeEngine(cfg, params, n_slots=2, max_len=32,
                           prefill_chunk=4, block_size=4,
                           prefill_mesh=pm, decode_mesh=dm)
    assert eng.staged_prefill                          # handoff path
    outs = eng.run(reqs)
    refs = lockstep_refs(cfg, params, reqs, 32)
    for o in outs:
        assert np.array_equal(o.tokens, refs[o.uid]), f"uid {o.uid}"
    leaf = jax.tree_util.tree_leaves(eng.state)[0]
    assert set(leaf.devices()) <= set(dm.devices.flat)
    assert eng.stats.blocks_in_use == 0


def test_disaggregated_mesh_validation():
    with pytest.raises(ValueError, match="devices"):
        make_disaggregated_meshes(prefill=(2, 16, 16), decode=(2, 16, 16))


# ------------------------------------------------------------------ stats
def test_servestats_defaults_and_alias():
    """Satellite: decode_utilization on a fresh engine is 0.0 (not a
    ZeroDivisionError), the pool counters start at zero, and the old
    EngineStats name still resolves."""
    st = ServeStats()
    assert st.decode_utilization == 0.0
    assert st.blocks_in_use == st.evictions == st.prefix_block_hits == 0
    assert st.admission_waits == st.peak_blocks_in_use == 0
    assert EngineStats is ServeStats


def test_block_allocator_unit():
    alloc = BlockAllocator(4, 2)
    a, b = alloc.alloc(2)
    assert alloc.blocks_in_use == 2
    key = alloc.prefix_key(np.asarray([1, 2, 3, 4], np.int32), 0)
    alloc.register(a, key)
    prompt = np.asarray([1, 2, 9], np.int32)
    assert alloc.match_prefix(prompt) == [a]
    alloc.claim(a)
    alloc.release(a)
    assert alloc.match_prefix(prompt) == [a]           # still refcounted
    alloc.release(a)
    assert alloc.match_prefix(prompt) == []            # unregistered
    alloc.release(b)
    assert alloc.blocks_in_use == 0
    with pytest.raises(RuntimeError, match="pool exhausted"):
        alloc.alloc(5)
