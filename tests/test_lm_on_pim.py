"""Assigned-LM-zoo -> PIM workload bridge tests."""

import pytest

from repro import configs
from repro.core import energy as en
from repro.core.lm_workloads import from_arch_config


@pytest.mark.parametrize("arch", list(configs.ASSIGNED))
def test_macs_match_active_params(arch):
    """One forward over T tokens through the weight-static matmuls must cost
    ~2 * N_active_linear * T MACs (embeddings excluded: gathers, not MVMs)."""
    cfg = configs.get(arch)
    T = 64
    layers = from_arch_config(cfg, tokens=T)
    macs = sum(l.macs for l in layers)
    # active linear params = active params minus the embedding table
    n_lin = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    assert 0.5 < macs / (n_lin * T) < 1.6, (macs / T, n_lin)


def test_moe_footprint_vs_active():
    """MoE archs place all experts on crossbars but only top-k MACs flow."""
    cfg = configs.get("phi3.5-moe-42b")
    layers = from_arch_config(cfg, tokens=256)
    moe = [l for l in layers if "_e" in l.name]
    assert len(moe) == cfg.n_layers * cfg.n_experts * 3
    active_frac = cfg.experts_per_token / cfg.n_experts
    dense_w = sum(l.weights for l in moe)
    macs = sum(l.macs for l in moe)
    assert macs / (dense_w * 256) == pytest.approx(active_frac, rel=0.1)


def test_raella_beats_isaac_on_lm_zoo():
    cfg = configs.get("yi-6b")
    layers = from_arch_config(cfg, tokens=128)
    ri = en.analyze_dnn(en.ISAAC_8B, layers, replicate=False)
    rr = en.analyze_dnn(en.RAELLA, layers, replicate=False)
    assert 2.0 < ri.energy / rr.energy < 5.0
