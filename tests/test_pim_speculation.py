"""Property tests for Dynamic Input Slicing (speculation + recovery).

Across *random* speculative slicings (any composition of the 8 input
bits into 1..4b parts):

- with a non-saturating (24b) ADC, ``speculation.forward`` is bit-exact
  with the non-speculative exact path (static 1b input slicing) *and*
  with the ideal unsigned-domain matmul, speculation never fails, and
  the convert economy holds: ``adc_converts <= no_spec_converts`` (one
  convert per spec slice instead of eight 1b converts);
- with the paper's saturating 7b ADC, the work-accounting invariants
  hold: every failure is an attempt, every recovery convert is billed to
  a failure (``attempts <= converts <= attempts + max_width *
  failures``), and the cycle count is spec slices + 8 recovery cycles.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import slicing as sl
from repro.core import speculation as spec

WIDE_ADC = adc_lib.ADCConfig(bits=24, signed=True)
SLICINGS = sl.enumerate_slicings(sl.INPUT_BITS, sl.MAX_DEVICE_BITS)

ROWS, COLS, BATCH = 96, 6, 3


def _layer(seed: int):
    rng = np.random.default_rng(seed)
    w_u = rng.integers(0, 256, (ROWS, COLS)).astype(np.int64)
    x = jnp.asarray(rng.integers(0, 256, (BATCH, ROWS)))
    return w_u, x


@hypothesis.given(st.integers(0, 2**31 - 1), st.sampled_from(SLICINGS))
@hypothesis.settings(max_examples=8, deadline=None)
def test_bit_exact_and_convert_economy_wide_adc(seed, spec_slicing):
    w_u, x = _layer(seed)
    enc = co.encode(w_u, (4, 2, 2))
    psum, stats = spec.forward(x, enc, spec_slicing, WIDE_ADC)
    psum_ref, _ = xbar.forward(x, enc, (1,) * sl.INPUT_BITS, WIDE_ADC)
    np.testing.assert_array_equal(np.asarray(psum), np.asarray(psum_ref))
    np.testing.assert_array_equal(
        np.asarray(psum), np.asarray(xbar.matmul_reference(x, w_u)))
    # lossless converter: nothing saturates, so no recovery converts and
    # speculation strictly beats the recovery-only (8 converts/col) design
    assert int(stats.spec_failures) == 0
    assert int(stats.adc_converts) == int(stats.spec_attempts)
    assert int(stats.adc_converts) <= int(stats.no_spec_converts)
    assert int(stats.recovery_saturations) == 0


@hypothesis.given(st.integers(0, 2**31 - 1), st.sampled_from(SLICINGS))
@hypothesis.settings(max_examples=8, deadline=None)
def test_failure_accounting_raella_adc(seed, spec_slicing):
    w_u, x = _layer(seed)
    enc = co.encode(w_u, (4, 2, 2))
    _, stats = spec.forward(x, enc, spec_slicing, adc_lib.RAELLA_ADC)
    attempts = int(stats.spec_attempts)
    failures = int(stats.spec_failures)
    converts = int(stats.adc_converts)
    n_conversion_sites = BATCH * enc.n_segments * enc.cols
    # every (column x spec-slice x weight-slice) conversion is attempted
    assert attempts == n_conversion_sites * enc.n_slices * len(spec_slicing)
    assert 0 <= failures <= attempts
    assert 0.0 <= float(stats.failure_rate) <= 1.0
    # recovery bills `width` extra 1b converts per failed conversion
    assert attempts <= converts <= attempts + max(spec_slicing) * failures
    if failures == 0:
        assert converts <= int(stats.no_spec_converts)
    assert int(stats.recovery_saturations) >= 0
    assert stats.cycles == len(spec_slicing) + sl.INPUT_BITS
    assert stats.macs == BATCH * ROWS * COLS
