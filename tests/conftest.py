"""Shared test setup: fake multi-device CPU, jax compat shims, hypothesis
fallback, and the fixed-seed RNG / small-mesh fixtures."""

import os
import sys

# Fake CPU devices so mesh/sharding tests exercise real partitioning.
# Must be in place before the jax backend initializes (conftest imports
# run before any test module, so this is the safe spot).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import testing as repro_testing  # noqa: E402

repro_testing.install_hypothesis_fallback()

import repro.dist  # noqa: E402,F401  (installs jax API compat shims)

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    """Fixed-seed numpy Generator — deterministic across runs."""
    return np.random.default_rng(0)


@pytest.fixture
def small_mesh():
    """Concrete 2x2 ("data", "model") mesh over fake CPU devices."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 (fake) devices")
    return jax.make_mesh((2, 2), ("data", "model"))


@pytest.fixture
def abstract_mesh():
    """Device-free 2x2 ("data", "model") mesh for rule-resolution tests."""
    return jax.sharding.AbstractMesh((2, 2), ("data", "model"))
