"""Titanium Law energy/throughput model vs the paper's published numbers."""

import numpy as np
import pytest

from repro.core import energy as en
from repro.core import mapping as mp
from repro.core import workloads as wl


def _geomean(xs):
    return float(np.exp(np.mean(np.log(xs))))


@pytest.fixture(scope="module")
def layer_sets():
    return {n: f() for n, f in wl.WORKLOADS.items()}


class TestConvertsPerMac:
    """Fig. 14 progression — exact combinatorics, no calibration."""

    def test_ideal_sequence(self):
        def ideal(a):
            return a.n_weight_slices * a.converts_per_column_pass() / a.rows
        assert ideal(en.ISAAC_8B) == pytest.approx(0.25)
        assert ideal(en.CENTER_OFFSET_ONLY) == pytest.approx(0.063, abs=0.002)
        assert ideal(en.CENTER_ADAPTIVE) == pytest.approx(0.047, abs=0.002)
        assert ideal(en.RAELLA) == pytest.approx(0.018, abs=0.002)

    def test_convert_reduction_up_to_14x(self):
        isaac = en.ISAAC_8B.n_weight_slices * en.ISAAC_8B.converts_per_column_pass() / 128
        raella = en.RAELLA.n_weight_slices * en.RAELLA.converts_per_column_pass() / 512
        assert 12 < isaac / raella < 15  # paper: "up to 14x fewer ADC converts"

    def test_measured_monotone(self, layer_sets):
        seq = [en.ISAAC_8B, en.CENTER_OFFSET_ONLY, en.CENTER_ADAPTIVE, en.RAELLA]
        vals = [en.analyze_dnn(a, layer_sets["resnet18"]).converts_per_mac
                for a in seq]
        assert vals == sorted(vals, reverse=True)


class TestTitaniumLaw:
    def test_equation(self):
        # E = E/convert x converts/MAC x MACs x 1/util
        assert en.titanium_law(2.0, 0.25, 100, 0.5) == pytest.approx(100.0)

    def test_adc_energy_scaling(self):
        assert en.adc_energy_per_convert(8) / en.adc_energy_per_convert(7) \
            == pytest.approx(2.0)  # exponential in resolution [65]


class TestFig12:
    """Efficiency/throughput vs 8b ISAAC across the seven DNNs."""

    def test_efficiency_geomean(self, layer_sets):
        ratios = [en.analyze_dnn(en.ISAAC_8B, ls).energy
                  / en.analyze_dnn(en.RAELLA, ls).energy
                  for ls in layer_sets.values()]
        g = _geomean(ratios)
        assert 3.3 <= g <= 4.5, g  # paper: 3.9x geomean
        assert min(ratios) > 2.0 and max(ratios) < 5.5  # paper: 2.9-4.9x

    def test_throughput_geomean(self, layer_sets):
        ratios = [en.analyze_dnn(en.ISAAC_8B, ls).latency_ns
                  / en.analyze_dnn(en.RAELLA, ls).latency_ns
                  for ls in layer_sets.values()]
        g = _geomean(ratios)
        assert 1.6 <= g <= 2.5, g  # paper: 2.0x geomean
        assert min(ratios) < 1.0, ratios  # paper: compact DNNs can be slower (0.7x)

    def test_no_spec_tradeoff(self, layer_sets):
        """Without speculation: lower efficiency gain, higher throughput gain."""
        eff_s, eff_n, th_s, th_n = [], [], [], []
        for ls in layer_sets.values():
            ei = en.analyze_dnn(en.ISAAC_8B, ls)
            es_ = en.analyze_dnn(en.RAELLA, ls)
            nn = en.analyze_dnn(en.RAELLA_NO_SPEC, ls)
            eff_s.append(ei.energy / es_.energy)
            eff_n.append(ei.energy / nn.energy)
            th_s.append(ei.latency_ns / es_.latency_ns)
            th_n.append(ei.latency_ns / nn.latency_ns)
        assert _geomean(eff_n) < _geomean(eff_s)   # spec buys efficiency
        assert _geomean(th_n) > _geomean(th_s)     # ...at a throughput cost
        assert 2.3 <= _geomean(th_n) <= 3.2        # paper: 2.7x

    def test_isaac_adc_dominated(self, layer_sets):
        rep = en.analyze_dnn(en.ISAAC_8B, layer_sets["resnet18"], replicate=False)
        share = rep.energy_breakdown["e_adc"] / rep.energy
        assert share >= 0.45  # Fig. 1: ADCs dominate PIM energy

    def test_raella_adc_share_reduced(self, layer_sets):
        rep = en.analyze_dnn(en.RAELLA, layer_sets["resnet18"], replicate=False)
        share = rep.energy_breakdown["e_adc"] / rep.energy
        assert share < 0.25

    def test_compact_dnns_gain_least(self, layer_sets):
        """Paper §6.3: small filters poorly utilize RAELLA's large crossbars."""
        gains = {n: en.analyze_dnn(en.ISAAC_8B, ls).energy
                 / en.analyze_dnn(en.RAELLA, ls).energy
                 for n, ls in layer_sets.items()}
        assert gains["mobilenet_v2"] == min(gains.values())


class TestMapping:
    def test_segmentation(self):
        l = mp.LayerShape("x", filter_len=1100, n_filters=64, n_positions=10)
        m = mp.map_layer(l, 512, 512, 3)
        assert m.n_segments == 3
        assert m.n_crossbars == 3 * 1  # 64 filters at 170/xbar -> 1

    def test_depthwise_poor_utilization(self):
        l = mp.LayerShape("dw", filter_len=9, n_filters=128, n_positions=100,
                          depthwise=True)
        m = mp.map_layer(l, 512, 512, 3)
        assert m.utilization < 0.1

    def test_toeplitz_only_for_short_filters(self):
        short = mp.map_layer(mp.LayerShape("s", 100, 8, 50), 512, 512, 3)
        long_ = mp.map_layer(mp.LayerShape("l", 1000, 8, 50), 512, 512, 3)
        assert short.toeplitz_positions > 1
        assert long_.toeplitz_positions == 1

    def test_replication_respects_budget(self):
        layers = [mp.LayerShape(f"l{i}", 512, 512, 1000) for i in range(4)]
        maps = [mp.map_layer(l, 512, 512, 3) for l in layers]
        lats = [1000.0, 2000.0, 4000.0, 8000.0]
        out = mp.greedy_replicate(maps, lats, total_crossbars=64)
        used = sum(m.n_crossbars * m.replication for m in out)
        assert used <= 64
        # slower layers get at least as many copies
        reps = [m.replication for m in out]
        assert reps == sorted(reps)
