"""The per-site adaptive-slicing compiler (``repro.models.pim_compile``).

Acceptance contract of the compiler refactor:

- with ``pim_weight_slicing="adaptive"`` the compiler chooses *different*
  slicings for different projection sites of a hybrid (attn + mamba +
  MoE) arch, with the paper's conservative 1b-per-slice override for
  ``lm_head``;
- chosen slicings are ragged across the instances stacked into one
  scan/vmap leaf, so planes are padded to the max slice count with
  ``slice_valid`` masks and per-instance ``slice_shifts``;
- ``plan_specs`` mirrors the new leaves and resolves under SERVE_RULES;
- exact mode stays bit-exact vs the int8 ideal-quantized reference at a
  wide ADC *under per-site slicings*, through greedy prefill + decode;
- the hoisted stacked exact-prepare (one grouped Center+Offset encode,
  one vmapped calibration trace) matches per-instance
  ``pim_linear.prepare`` bit-for-bit;
- ``measure_errors`` (single host sync per candidate group) matches
  per-candidate ``measure_error``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import adaptive as ad
from repro.core import pim_linear as pl
from repro.core import slicing as sl
from repro.models import pim
from repro.models import pim_compile
from repro.models import transformer as T

CONSERVATIVE = (1,) * sl.WEIGHT_BITS


def _hybrid_cfg(**over) -> ArchConfig:
    """Attn + mamba + MoE toy arch with mixed projection row counts, so
    Algorithm 1 lands on genuinely different slicings per site."""
    base = dict(
        name="hybrid-toy", family="hybrid", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=144, vocab_size=64,
        n_experts=2, experts_per_token=1, moe_every=2, capacity_factor=4.0,
        block_pattern=("attn", "mamba"), mamba_d_state=8, remat=False,
        pim_mode="exact", pim_weight_slicing="adaptive")
    base.update(over)
    return ArchConfig(**base)


def _calib(cfg, b=2, s=8, seed=2):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (b, s), 0, cfg.vocab_size), np.int32)


@pytest.fixture(scope="module")
def adaptive_setup():
    cfg = _hybrid_cfg()
    params, _ = T.init_params(cfg, jax.random.key(0))
    # squash most rows of expert 0's down-projection: its column sums stay
    # small, so Algorithm 1 picks fewer slices for it than for expert 1 —
    # a *ragged* slicing within one vmapped expert leaf
    w2 = params["blocks"][0]["ffn"]["w2"]
    params["blocks"][0]["ffn"]["w2"] = w2.at[0, 0, 24:, :].set(0.0)
    calib = _calib(cfg)
    compiled = pim_compile.compile_pim_params(params, cfg, calib)
    return cfg, params, calib, compiled


class TestAdaptiveChoices:
    def test_distinct_slicings_across_sites(self, adaptive_setup):
        """Acceptance: at least two distinct slicings across projection
        sites — and not merely via the lm_head override."""
        _, _, _, compiled = adaptive_setup
        non_head = {s.slicing for s in compiled.sites
                    if s.site != "embed.head"}
        assert len(non_head) >= 2, non_head
        assert len(compiled.distinct_slicings()) >= 3

    def test_lm_head_conservative(self, adaptive_setup):
        _, _, _, compiled = adaptive_setup
        head = compiled.site("embed.head")
        assert head.slicing == CONSERVATIVE
        assert head.last_layer

    def test_site_table_is_complete(self, adaptive_setup):
        """One SitePlan per projection instance: 4 attn + 3 mamba +
        3 dense FFN + 3 MoE mats x 2 experts + head = 17."""
        cfg, _, _, compiled = adaptive_setup
        assert len(compiled.sites) == 17
        assert all(s.error is not None for s in compiled.sites)
        assert all(s.search_adc_bits == cfg.pim_search_adc_bits
                   for s in compiled.sites)

    def test_tuple_mode_pins_every_site(self):
        """A tuple keeps today's fixed behavior: every site (incl. head)
        gets the tuple, nothing is measured."""
        cfg = _hybrid_cfg(n_experts=0, experts_per_token=0,
                          pim_weight_slicing=(4, 2, 2), pim_mode="fast")
        params, _ = T.init_params(cfg, jax.random.key(0))
        compiled = pim_compile.compile_pim_params(params, cfg, _calib(cfg))
        assert compiled.distinct_slicings() == ((4, 2, 2),)
        assert all(s.error is None for s in compiled.sites)


class TestRaggedPlans:
    def test_expert_leaf_is_ragged_with_valid_masks(self, adaptive_setup):
        """The doctored expert 0 chose fewer slices than expert 1; the
        shared leaf is padded to the max with the mask marking padding."""
        _, _, _, compiled = adaptive_setup
        s0 = compiled.site("blocks[0].ffn.w2[r0,e0]")
        s1 = compiled.site("blocks[0].ffn.w2[r0,e1]")
        assert s0.n_slices < s1.n_slices
        leaf = compiled.plans["blocks"][0]["ffn"]["w2"]
        valid = np.asarray(leaf["slice_valid"])[0]      # (E, n_max)
        n_max = max(s0.n_slices, s1.n_slices)
        assert valid.shape == (2, n_max)
        np.testing.assert_array_equal(valid.sum(axis=1),
                                      [s0.n_slices, s1.n_slices])
        # padding planes are zeroed — a numerical no-op at the signed ADC
        planes = np.asarray(leaf["planes"])[0]          # (E, n_max, ...)
        assert not planes[0, s0.n_slices:].any()

    def test_shifts_match_slice_bounds(self, adaptive_setup):
        _, _, _, compiled = adaptive_setup
        leaf = compiled.plans["blocks"][0]["ffn"]["w2"]
        shifts = np.asarray(leaf["slice_shifts"])[0]    # (E, n_max)
        for e in ("e0", "e1"):
            sp = compiled.site(f"blocks[0].ffn.w2[r0,{e}]")
            want = sl.slice_shifts(sp.slicing, sl.WEIGHT_BITS)
            got = tuple(shifts[int(e[1])][:sp.n_slices])
            assert got == want


class TestSpecsMirror:
    def test_plan_specs_mirror_plans_with_slice_leaves(self, adaptive_setup,
                                                       abstract_mesh):
        import jax.sharding as jsh

        from repro.dist import sharding as dist_sharding
        cfg, _, _, compiled = adaptive_setup
        plans, specs = compiled.plans, compiled.specs
        assert (jax.tree.structure(jax.tree.map(lambda _: 0, plans))
                == jax.tree.structure(
                    jax.tree.map(lambda _: 0, specs,
                                 is_leaf=lambda x: isinstance(x, tuple))))
        # slice tables keep the stack axes (repeat None, experts) and
        # replicate the padded slice axis
        leaf = specs["blocks"][0]["ffn"]["w2"]
        assert leaf["slice_shifts"] == (None, "experts", None)
        assert leaf["slice_valid"] == (None, "experts", None)
        # every spec has one axis per array dim, incl. the new slice tables
        for name, spec in leaf.items():
            arr = plans["blocks"][0]["ffn"]["w2"][name]
            assert len(spec) == arr.ndim, name
        with dist_sharding.axis_rules(dist_sharding.SERVE_RULES):
            resolved = jax.tree.map(
                lambda s: dist_sharding.spec_for(s, abstract_mesh),
                specs, is_leaf=lambda x: isinstance(x, tuple))
        for p in jax.tree.leaves(
                resolved, is_leaf=lambda x: isinstance(x, jsh.PartitionSpec)):
            assert isinstance(p, jsh.PartitionSpec)

    def test_prepare_pim_params_facade(self, adaptive_setup):
        """The stable 2-tuple surface delegates to the compiler."""
        cfg, params, calib, compiled = adaptive_setup
        plans, specs = pim.prepare_pim_params(params, cfg, calib)
        jax.tree.map(np.testing.assert_array_equal, plans, compiled.plans)
        assert specs == compiled.specs


class TestExactBitExact:
    def test_exact_equals_int8_through_greedy_prefill_decode(
            self, adaptive_setup):
        """Acceptance: per-site (ragged) slicings keep the exact datapath
        bit-exact vs the int8 reference at the wide (24b) ADC — any
        slicing reconstructs the weights exactly when the ADC never
        saturates, so heterogeneity must not change a single bit."""
        cfg, params, calib, compiled = adaptive_setup
        cfg_i8 = dataclasses.replace(cfg, pim_mode="int8")
        plans = compiled.plans

        lg_e = T.forward(params, cfg, jnp.asarray(calib), plans=plans)
        lg_i = T.forward(params, cfg_i8, jnp.asarray(calib), plans=plans)
        np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_i))

        lg_e, st_e = T.prefill(params, cfg, jnp.asarray(calib),
                               max_len=12, plans=plans)
        lg_i, st_i = T.prefill(params, cfg_i8, jnp.asarray(calib),
                               max_len=12, plans=plans)
        np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_i))
        for _ in range(3):
            tok = jnp.argmax(lg_e[:, -1:], -1)
            lg_e, st_e = T.decode_step(params, cfg, st_e, tok, plans=plans)
            lg_i, st_i = T.decode_step(params, cfg_i8, st_i, tok,
                                       plans=plans)
            np.testing.assert_array_equal(np.asarray(lg_e),
                                          np.asarray(lg_i))


class TestStackedPrepare:
    def test_matches_per_instance_prepare(self):
        """The hoisted group-encode (instances folded into the column
        axis) reproduces per-instance ``pim_linear.prepare`` bit-for-bit,
        ragged slicings included."""
        rng = np.random.default_rng(0)
        K, R, C = 3, 70, 12
        wf = jnp.asarray(rng.normal(0, 0.05, (K, R, C)), jnp.float32)
        xf = jnp.asarray(rng.normal(0, 0.5, (K, 6, R)), jnp.float32)
        slicings = [(4, 4), (4, 2, 2), CONSERVATIVE]
        leaf = pim_compile._exact_prepare_stacked(wf, xf, slicings)
        n_max = max(len(s) for s in slicings)
        assert leaf["planes"].shape[:2] == (K, n_max)
        for k, s in enumerate(slicings):
            ref = pl.prepare(wf[k], xf[k], weight_slicing=s,
                             signed_inputs=True)
            np.testing.assert_array_equal(
                np.asarray(leaf["w_q"][k]), np.asarray(ref.w_q))
            np.testing.assert_array_equal(
                np.asarray(leaf["planes"][k][:len(s)]),
                np.asarray(ref.enc.planes))
            assert not np.asarray(leaf["planes"][k][len(s):]).any()
            np.testing.assert_array_equal(
                np.asarray(leaf["enc_centers"][k]),
                np.asarray(ref.enc.centers))
            assert tuple(np.asarray(leaf["slice_shifts"][k])[:len(s)]) \
                == ref.enc.shifts
            np.testing.assert_array_equal(
                np.asarray(leaf["slice_valid"][k]),
                [True] * len(s) + [False] * (n_max - len(s)))


class TestBatchedMeasure:
    def test_measure_errors_matches_singles(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(0, 0.05, (96, 10)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 0.4, (8, 96)), jnp.float32)
        cands = [(4, 4), (4, 2, 2), (2, 2, 2, 2)]
        batch = ad.measure_errors(w, x, cands)
        singles = [ad.measure_error(w, x, s) for s in cands]
        np.testing.assert_allclose(batch, np.asarray(singles, np.float32),
                                   rtol=0, atol=0)

    def test_find_best_slicing_all_errors_are_floats(self):
        """The batched group evaluation still reports every tried
        candidate (host-side floats, one sync per group)."""
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.normal(0, 0.04, (128, 12)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 0.4, (8, 128)), jnp.float32)
        choice = ad.find_best_slicing(w, x)
        assert choice.slicing in choice.all_errors
        assert all(isinstance(e, float)
                   for e in choice.all_errors.values())
        for s, e in choice.all_errors.items():
            if len(s) < choice.n_slices:
                assert e >= ad.ERROR_BUDGET


class TestAdaptiveFastMode:
    def test_fast_adaptive_serves(self):
        """'adaptive' composes with the fast path: the search drives the
        architecture table (and energy report); the Eq. 1 int8 numerics
        are slicing-independent, so fast output matches a pinned-slicing
        fast compile exactly."""
        cfg = _hybrid_cfg(n_layers=2, d_model=32, d_ff=48, head_dim=16,
                          pim_mode="fast")
        params, _ = T.init_params(cfg, jax.random.key(0))
        calib = _calib(cfg)
        compiled = pim_compile.compile_pim_params(params, cfg, calib)
        assert compiled.site("embed.head").slicing == CONSERVATIVE
        cfg_pin = dataclasses.replace(cfg, pim_weight_slicing=(4, 2, 2))
        plans_pin, _ = pim.prepare_pim_params(params, cfg_pin, calib)
        lg_a = T.forward(params, cfg, jnp.asarray(calib),
                         plans=compiled.plans)
        lg_p = T.forward(params, cfg_pin, jnp.asarray(calib),
                         plans=plans_pin)
        np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_p))


class TestReport:
    def test_report_prices_every_site(self, adaptive_setup):
        """Energy report: per-site converts/MAC + energy, slice histogram,
        whole-model aggregates — all JSON-serializable."""
        import json
        _, _, _, compiled = adaptive_setup
        rep = compiled.report(tokens=64)
        json.dumps(rep)
        assert rep["n_sites"] == len(compiled.sites) == len(rep["sites"])
        assert sum(compiled.slice_histogram().values()) == rep["n_sites"]
        for row in rep["sites"]:
            assert row["converts_per_mac"] > 0
            assert 0 < row["adc_share"] < 1
        # the conservative head needs more converts/MAC than a 2-slice site
        by_site = {r["site"]: r for r in rep["sites"]}
        head = by_site["embed.head"]
        two_slice = next(r for r in rep["sites"] if r["n_slices"] == 2)
        assert head["converts_per_mac"] > two_slice["converts_per_mac"]
