"""Docs stay true: README/docs code blocks execute, intra-repo links
resolve, and the paper↔code map covers every repro.core module."""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

DOC_FILES = [ROOT / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

_CODE_BLOCK = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def python_blocks(path):
    return _CODE_BLOCK.findall(path.read_text())


def test_readme_and_docs_exist():
    assert (ROOT / "README.md").is_file()
    assert (ROOT / "docs" / "architecture.md").is_file()
    assert (ROOT / "docs" / "sharding.md").is_file()


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_intra_repo_links_resolve(path):
    """Every relative markdown link points at a file that exists."""
    broken = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if rel and not (path.parent / rel).exists():
            broken.append(target)
    assert not broken, f"{path.name}: broken links {broken}"


@pytest.mark.parametrize(
    "path", [p for p in DOC_FILES if python_blocks(p)],
    ids=lambda p: p.name)
def test_python_blocks_execute(path):
    """Doctest the quickstart/worked-example snippets: each ```python
    block must run as-is (they are what a new user copy-pastes)."""
    for i, block in enumerate(python_blocks(path)):
        try:
            exec(compile(block, f"{path.name}[block {i}]", "exec"), {})
        except Exception as e:  # pragma: no cover - failure reporting
            pytest.fail(f"{path.name} python block {i} raised "
                        f"{type(e).__name__}: {e}\n---\n{block}")


def test_architecture_map_covers_every_core_module():
    """Acceptance: the paper↔code map names every repro.core module."""
    text = (ROOT / "docs" / "architecture.md").read_text()
    missing = [p.name for p in sorted((ROOT / "src/repro/core").glob("*.py"))
               if p.name != "__init__.py" and p.name not in text]
    assert not missing, f"architecture.md does not map {missing}"


def test_readme_quickstart_points_at_real_example():
    readme = (ROOT / "README.md").read_text()
    assert "examples/quickstart.py" in readme
    assert (ROOT / "examples" / "quickstart.py").is_file()
