"""Unit + property tests for bit-slicing and Center+Offset encoding."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core import center_offset as co
from repro.core import slicing as sl


class TestSlicings:
    def test_enumeration_count(self):
        assert len(sl.enumerate_slicings(8, 4)) == 108  # paper §4.2.2

    def test_enumeration_valid(self):
        for s in sl.enumerate_slicings(8, 4):
            assert sum(s) == 8
            assert all(1 <= b <= 4 for b in s)

    def test_bounds(self):
        assert sl.slice_bounds((4, 2, 2)) == ((7, 4), (3, 2), (1, 0))
        assert sl.slice_bounds((1,) * 8) == tuple((b, b) for b in range(7, -1, -1))

    def test_shifts(self):
        assert sl.slice_shifts((4, 2, 2)) == (4, 2, 0)


class TestCropReconstruct:
    @hypothesis.given(st.integers(-255, 255),
                      st.sampled_from(sl.enumerate_slicings(8, 4)))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_signed_roundtrip(self, x, slicing):
        xs = jnp.asarray([x])
        slices = sl.slice_signed(xs, slicing)
        rec = sl.reconstruct(slices, slicing)
        assert int(rec[0]) == x

    @hypothesis.given(st.integers(0, 255),
                      st.sampled_from(sl.enumerate_slicings(8, 4)))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_unsigned_roundtrip(self, x, slicing):
        xs = jnp.asarray([x])
        slices = sl.slice_unsigned(xs, slicing)
        rec = sl.reconstruct(slices, slicing)
        assert int(rec[0]) == x

    def test_slice_value_range(self):
        x = jnp.arange(-255, 256)
        for slicing in [(4, 4), (4, 2, 2), (1,) * 8]:
            for s, width in zip(sl.slice_signed(x, slicing), slicing):
                assert int(jnp.max(jnp.abs(s))) <= 2 ** width - 1

    def test_reslice_to_1b(self):
        x = jnp.asarray([13, -13, 0, 15])
        subs = sl.reslice_to_1b(x, 4)
        rec = sum(s.astype(jnp.int32) << b for s, b in zip(subs, [3, 2, 1, 0]))
        np.testing.assert_array_equal(np.asarray(rec), [13, -13, 0, 15])


class TestCenterOffset:
    def test_encode_decode_exact(self):
        rng = np.random.default_rng(0)
        w = rng.integers(0, 256, size=(300, 17), dtype=np.int64)
        for mode in ["center", "zero"]:
            enc = co.encode(w, (4, 2, 2), mode=mode)
            np.testing.assert_array_equal(co.decode(enc), w)

    def test_encode_decode_multi_segment(self):
        rng = np.random.default_rng(1)
        w = rng.integers(0, 256, size=(1100, 5), dtype=np.int64)
        enc = co.encode(w, (4, 4))
        assert enc.n_segments == 3
        np.testing.assert_array_equal(co.decode(enc), w)

    def test_centers_balance_columns(self):
        """Eq. 2 centers beat zero-centers on their own cost objective, and
        reduce the mean |column sum of slices| for skewed filters."""
        rng = np.random.default_rng(2)
        # mostly-negative weights in the signed domain (paper Fig. 5 setup)
        w_signed = np.clip(rng.normal(-40, 25, size=(512, 8)), -127, 127)
        w = (w_signed + 128).astype(np.int64)
        slicing = (4, 2, 2)
        enc_c = co.encode(w, slicing, mode="center")
        enc_z = co.encode(w, slicing, mode="zero")

        def mean_abs_colsum(enc):
            return np.abs(enc.planes.astype(np.int64).sum(axis=2)).mean()

        assert mean_abs_colsum(enc_c) < mean_abs_colsum(enc_z)

    def test_center_term_matches_decode(self):
        rng = np.random.default_rng(3)
        w = rng.integers(0, 256, size=(600, 4), dtype=np.int64)
        x = jnp.asarray(rng.integers(0, 256, size=(5, 600)))
        enc = co.encode(w, (4, 2, 2))
        ct = co.center_term(x, enc)
        # brute force: sum over segments of phi_seg * sum(x_seg)
        xp = np.pad(np.asarray(x), ((0, 0), (0, enc.n_segments * 512 - 600)))
        xs = xp.reshape(5, enc.n_segments, 512)
        want = np.einsum("bs,sc->bc", xs.sum(-1), enc.centers)
        np.testing.assert_array_equal(np.asarray(ct), want)

    @hypothesis.given(st.integers(0, 2 ** 32 - 1))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_encode_decode_property(self, seed):
        rng = np.random.default_rng(seed)
        rows = int(rng.integers(1, 700))
        cols = int(rng.integers(1, 6))
        slicing = sl.enumerate_slicings()[int(rng.integers(0, 108))]
        w = rng.integers(0, 256, size=(rows, cols), dtype=np.int64)
        enc = co.encode(w, slicing)
        np.testing.assert_array_equal(co.decode(enc), w)
