"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + no-NaN assertions; prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T

ALL_ARCHS = sorted(configs.REGISTRY)


def _inputs(cfg, key, B=2, S=16):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = configs.get(arch).reduced()
        key = jax.random.key(0)
        params, specs = T.init_params(cfg, key)
        x = _inputs(cfg, key)
        logits = T.forward(params, cfg, x)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())

    def test_one_train_step(self, arch):
        cfg = configs.get(arch).reduced()
        key = jax.random.key(1)
        params, _ = T.init_params(cfg, key)
        x = _inputs(cfg, key)
        labels = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        batch = {"inputs": x, "labels": labels}

        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, batch))(params)
        assert np.isfinite(float(loss))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
        gnorm = sum(float(jnp.sum(jnp.square(g))) for g in flat) ** 0.5
        assert gnorm > 0.0  # gradients actually flow

    def test_specs_match_params(self, arch):
        cfg = configs.get(arch).reduced()
        params, specs = T.init_params(cfg, jax.random.key(2))
        pt = jax.tree.structure(params)
        st = jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, tuple))
        assert pt == st
        # every spec has the right rank
        def chk(p, s):
            assert len(s) == p.ndim, (p.shape, s)
        jax.tree.map(chk, params, specs,
                     is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))

    def test_param_count_analytic_close(self, arch):
        cfg = configs.get(arch).reduced()
        params, _ = T.init_params(cfg, jax.random.key(3))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert 0.5 < actual / approx < 2.0, (actual, approx)


DECODE_ARCHS = [a for a in ALL_ARCHS if configs.get(a).causal]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode over a prompt must reproduce forward logits."""
    cfg = configs.get(arch).reduced()
    key = jax.random.key(4)
    params, _ = T.init_params(cfg, key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = T.forward(params, cfg, toks)  # (B, S, V)

    # prefill first S-2 tokens, then decode 2 steps teacher-forced
    split = S - 2
    logits_p, state = T.prefill(params, cfg, toks[:, :split], max_len=S)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, split - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(split, S):
        logits_d, state = T.decode_step(params, cfg, state, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-3b", "jamba-1.5-large-398b"])
def test_decode_from_scratch(arch):
    """Pure decode (no prefill) step-by-step equals forward."""
    cfg = configs.get(arch).reduced()
    key = jax.random.key(5)
    params, _ = T.init_params(cfg, key)
    B, S = 1, 6
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = T.forward(params, cfg, toks)
    state = T.init_decode_state(cfg, B, S)
    for t in range(S):
        logits, state = T.decode_step(params, cfg, state, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-2, atol=2e-2)


def test_moe_routes_tokens_to_experts():
    cfg = configs.get("phi3.5-moe-42b").reduced()
    params, _ = T.init_params(cfg, jax.random.key(6))
    x1 = _inputs(cfg, jax.random.key(7))
    x2 = _inputs(cfg, jax.random.key(8))
    l1 = T.forward(params, cfg, x1)
    l2 = T.forward(params, cfg, x2)
    assert not bool(jnp.allclose(l1, l2))  # routing is input-dependent


def test_registry_complete():
    assert len(configs.ASSIGNED) == 10
    assert "raella-bert-large" in configs.REGISTRY
    # skip rules (DESIGN.md §4): 31 runnable cells of the 40
    cells = sum(len(configs.runnable_shapes(configs.get(a)))
                for a in configs.ASSIGNED)
    assert cells == 31, cells
