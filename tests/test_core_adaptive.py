"""Algorithm 1 (Adaptive Weight Slicing) behaviour tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive as ad


def _layer(rng, rows=512, cols=24, w_scale=0.04, skew=0.0):
    w = rng.normal(skew, w_scale, size=(rows, cols)).astype(np.float32)
    x = np.maximum(rng.normal(0.2, 0.35, size=(10, rows)), 0).astype(np.float32)
    return jnp.asarray(w), jnp.asarray(x)


class TestMeasureError:
    def test_error_decreases_with_more_slices(self):
        rng = np.random.default_rng(0)
        w, x = _layer(rng, w_scale=0.12)
        e_coarse = ad.measure_error(w, x, (4, 4))
        e_fine = ad.measure_error(w, x, (1,) * 8)
        assert e_fine <= e_coarse

    def test_zero_offset_worse_than_center(self):
        rng = np.random.default_rng(1)
        # per-channel skew makes differential encoding saturate (Fig. 5)
        w = rng.normal(0, 0.03, size=(512, 16)) + rng.uniform(-0.08, 0.08, (1, 16))
        w = jnp.asarray(w, jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.3, 0.4, size=(10, 512)), 0),
                        jnp.float32)
        e_center = ad.measure_error(w, x, (4, 2, 2), encode_mode="center")
        e_zero = ad.measure_error(w, x, (4, 2, 2), encode_mode="zero")
        assert e_center < e_zero


class TestFindBestSlicing:
    def test_respects_budget(self):
        rng = np.random.default_rng(2)
        w, x = _layer(rng)
        choice = ad.find_best_slicing(w, x, error_budget=0.09)
        assert choice.error < 0.09

    def test_fewest_slices_preferred(self):
        """All candidate groups with fewer slices must have failed budget."""
        rng = np.random.default_rng(3)
        w, x = _layer(rng)
        choice = ad.find_best_slicing(w, x, error_budget=0.09)
        for s, e in choice.all_errors.items():
            if len(s) < choice.n_slices:
                assert e >= 0.09

    def test_last_layer_conservative(self):
        rng = np.random.default_rng(4)
        w, x = _layer(rng, rows=128, cols=8)
        choice = ad.find_best_slicing(w, x, last_layer=True)
        assert choice.slicing == (1,) * 8

    def test_noise_pushes_to_more_slices(self):
        """Fig. 15: adaptive slicing is noise-aware — more noise, more slices."""
        rng = np.random.default_rng(5)
        w, x = _layer(rng, cols=16, w_scale=0.05)
        clean = ad.find_best_slicing(w, x, error_budget=0.09)
        noisy = ad.find_best_slicing(w, x, error_budget=0.09,
                                     noise_level=0.10,
                                     key=jax.random.key(0))
        assert noisy.n_slices >= clean.n_slices

    def test_typical_layer_uses_three_slices(self):
        """Paper Fig. 7: most (bell-curve-weight) layers land on 3 slices."""
        rng = np.random.default_rng(6)
        w, x = _layer(rng, w_scale=0.04)
        choice = ad.find_best_slicing(w, x, error_budget=0.09)
        assert choice.n_slices <= 4  # 3 typical; allow 4 for sampling noise
