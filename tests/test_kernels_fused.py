"""Differential harness for the fused exact-datapath kernel.

``repro.kernels.fused_crossbar`` runs the whole RAELLA exact datapath
(in-kernel input slicing, slice-plane matmuls, per-segment signed ADC
clamp, shift-and-accumulate, digital center term, saturation counting)
in one launch. These tests lock it to three independent ground truths:

  1. the ``core.crossbar.forward`` Python loop (``backend='python'``) —
     the datapath the paper tables were produced with;
  2. the pure-jnp oracle ``kernels.ref.fused_crossbar``;
  3. standalone numpy loops written here (so a shared bug in the kernel
     *and* ``ref`` cannot hide).

Sweeps cover the 108 slicings on both operands, ADC bits 4..8, ragged
``slice_valid`` masks from adaptive per-site plans, and both interpret
and XLA backends — everything bit-exact, never approximate.
"""

import dataclasses
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import pim_linear
from repro.core import slicing as sl
from repro.kernels import fused_crossbar as fx
from repro.kernels import ops, ref

BACKENDS = ("interpret", "xla")


def _mk_layer(rng, rows, cols, B, weight_slicing, mode="center"):
    w_u = rng.integers(0, 256, (rows, cols)).astype(np.int64)
    enc = co.encode(w_u, weight_slicing, mode=mode)
    x = jnp.asarray(rng.integers(0, 256, (B, rows)))
    return w_u, enc, x


def _np_fused(x, planes, shifts, centers, input_slicing, lo, hi,
              rows_per_xbar=512):
    """Independent numpy oracle: full datapath, plain loops."""
    x = np.asarray(x, np.int64)
    planes = np.asarray(planes, np.int64)  # (n_j, n_seg, R, C)
    centers = np.asarray(centers, np.int64)
    n_j, n_seg, R, C = planes.shape
    B = x.shape[0]
    xp = np.zeros((B, n_seg * R), np.int64)
    xp[:, :x.shape[1]] = x
    xs = xp.reshape(B, n_seg, R)
    psum = np.einsum("bsr,sc->bc", xs, centers)
    sats = 0
    hi_bit = 7
    for w in input_slicing:
        li = hi_bit - w + 1
        x_i = (xs >> li) & ((1 << w) - 1)
        for j in range(n_j):
            cs = np.einsum("bsr,src->bsc", x_i, planes[j])
            cv = np.clip(cs, lo, hi)
            sats += int(((cv == lo) | (cv == hi)).sum())
            psum = psum + cv.sum(axis=1) * (1 << (li + int(shifts[j])))
        hi_bit -= w
    return psum, sats


class TestFusedDifferential:
    """Hypothesis sweep: random layer shapes x the 108 slicings on both
    operands x ADC bits 4..8, fused (both backends) vs the Python
    datapath, the jnp oracle, and the numpy oracle."""

    @hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(4, 8))
    @hypothesis.settings(max_examples=8, deadline=None)
    def test_vs_python_datapath_and_oracles(self, seed, adc_bits):
        rng = np.random.default_rng(seed)
        all_slicings = sl.enumerate_slicings()
        w_slicing = all_slicings[int(rng.integers(0, len(all_slicings)))]
        i_slicing = all_slicings[int(rng.integers(0, len(all_slicings)))]
        rows = int(rng.integers(1, 900))
        cols = int(rng.integers(1, 24))
        B = int(rng.integers(1, 5))
        _, enc, x = _mk_layer(rng, rows, cols, B, w_slicing)
        adc = adc_lib.ADCConfig(bits=adc_bits, signed=True)

        want, st_py = xbar.forward(x, enc, i_slicing, adc, backend="python")
        np_psum, np_sats = _np_fused(x, enc.planes, enc.shifts, enc.centers,
                                     i_slicing, adc.lo, adc.hi)
        np.testing.assert_array_equal(np.asarray(want, np.int64), np_psum)
        assert int(st_py.saturations) == np_sats
        for backend in BACKENDS:
            got, st_f = xbar.forward(x, enc, i_slicing, adc, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            assert int(st_f.saturations) == int(st_py.saturations)

    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    @hypothesis.settings(max_examples=6, deadline=None)
    def test_ragged_valid_masks(self, seed):
        """Adaptive per-site plans pad the slice axis: a padded encoding
        (zero planes + slice_valid mask + garbage padded shifts) must be
        bit-identical to the unpadded one, on every backend."""
        rng = np.random.default_rng(seed)
        all_slicings = sl.enumerate_slicings()
        w_slicing = all_slicings[int(rng.integers(0, len(all_slicings)))]
        rows = int(rng.integers(1, 700))
        cols = int(rng.integers(1, 16))
        _, enc, x = _mk_layer(rng, rows, cols, 3, w_slicing)
        n_s = enc.n_slices
        n_pad = int(rng.integers(1, 4))
        padded_planes = jnp.pad(jnp.asarray(enc.planes),
                                ((0, n_pad), (0, 0), (0, 0), (0, 0)))
        # padded shifts are arbitrary (the compiler writes 0; any value
        # must be inert because the multiplier is masked to 0)
        pad_shifts = rng.integers(0, 8, n_pad)
        shifts = jnp.asarray(list(enc.shifts) + list(pad_shifts), jnp.int32)
        valid = jnp.asarray([True] * n_s + [False] * n_pad)

        want, _ = ops.fused_crossbar_forward(
            x, jnp.asarray(enc.planes), jnp.asarray(enc.shifts, jnp.int32),
            jnp.asarray(enc.centers), input_slicing=(1,) * 8,
            adc_lo=-64, adc_hi=63, backend="xla")
        for backend in BACKENDS:
            got, sats = ops.fused_crossbar_forward(
                x, padded_planes, shifts, jnp.asarray(enc.centers),
                input_slicing=(1,) * 8, adc_lo=-64, adc_hi=63,
                valid=valid, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_vs_ref_oracle_direct(self, backend):
        """The registry's low-level impls agree with ``ref.fused_crossbar``
        on the raw (pre-wrapped) contract."""
        rng = np.random.default_rng(17)
        x = jnp.asarray(rng.integers(0, 256, (4, 1024)), jnp.int32)
        w = jnp.asarray(rng.integers(-15, 16, (3, 1024, 40)), jnp.int8)
        in_li = jnp.asarray([4, 2, 0], jnp.int32)
        in_mask = jnp.asarray([15, 3, 3], jnp.int32)
        mults = jnp.asarray(rng.choice([0, 1, 4, 64], (3, 3)), jnp.int32)
        cen = jnp.asarray(rng.integers(1, 256, (2, 40)), jnp.int32)
        want, wsat = ref.fused_crossbar(x, w, in_li, in_mask, mults, cen)
        got, gsat = ops.dispatch("fused_crossbar", backend)(
            x, w, in_li, in_mask, mults, cen, adc_lo=-64, adc_hi=63,
            rows_per_xbar=512, narrow=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(gsat) == int(wsat)


class TestFusedEdgeShapes:
    """Edge shapes on both backends vs the numpy oracle."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("rows,cols,B,w_slicing,i_slicing", [
        (1, 1, 1, (4, 4), (4, 4)),          # minimal everything
        (513, 3, 1, (4, 2, 2), (1,) * 8),   # R one past a segment (ragged)
        (512, 130, 2, (1,) * 8, (4, 4)),    # C off the 128 tile, max n_j
        (1025, 1, 4, (4, 2, 2), (2,) * 4),  # C=1, third ragged segment
        (300, 7, 1, (2, 2, 2, 2), (4, 2, 2)),  # everything off-tile
    ])
    def test_edges(self, rows, cols, B, w_slicing, i_slicing, backend):
        rng = np.random.default_rng(rows * 31 + cols * 7 + B)
        _, enc, x = _mk_layer(rng, rows, cols, B, w_slicing)
        got, st_f = xbar.forward(x, enc, i_slicing, backend=backend)
        np_psum, np_sats = _np_fused(x, enc.planes, enc.shifts, enc.centers,
                                     i_slicing, -64, 63)
        np.testing.assert_array_equal(np.asarray(got, np.int64), np_psum)
        assert int(st_f.saturations) == np_sats

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_n_slices_one(self, backend):
        """A single weight slice plane (n_j = 1), B = 1, via the raw op
        (a legal 8b weight slicing always has >= 2 slices, so this edge
        only exists at the kernel contract level)."""
        rng = np.random.default_rng(3)
        planes = rng.integers(-15, 16, (1, 1, 512, 6)).astype(np.int8)
        centers = rng.integers(1, 256, (1, 6)).astype(np.int32)
        x = jnp.asarray(rng.integers(0, 256, (1, 400)))
        psum, sats = ops.fused_crossbar_forward(
            x, jnp.asarray(planes), (0,), jnp.asarray(centers),
            input_slicing=(4, 2, 2), adc_lo=-64, adc_hi=63, backend=backend)
        np_psum, np_sats = _np_fused(x, planes, (0,), centers,
                                     (4, 2, 2), -64, 63)
        np.testing.assert_array_equal(np.asarray(psum, np.int64), np_psum)
        assert int(sats) == np_sats

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_all_padding_planes(self, backend):
        """Every slice plane masked invalid -> psum is exactly the digital
        center term and nothing saturates (signed ADC)."""
        rng = np.random.default_rng(4)
        _, enc, x = _mk_layer(rng, 300, 6, 2, (4, 2, 2))
        valid = jnp.zeros((enc.n_slices,), bool)
        psum, sats = ops.fused_crossbar_forward(
            x, jnp.asarray(enc.planes), jnp.asarray(enc.shifts, jnp.int32),
            jnp.asarray(enc.centers), input_slicing=(1,) * 8,
            adc_lo=-64, adc_hi=63, valid=valid, backend=backend)
        np.testing.assert_array_equal(np.asarray(psum),
                                      np.asarray(co.center_term(x, enc)))
        assert int(sats) == 0

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_saturation_at_clip_boundary(self, backend):
        """Column sums landing exactly on lo / hi count as saturated; one
        LSB inside the window does not (the paper's detection rule)."""
        adc = adc_lib.ADCConfig(bits=4, signed=True)  # [-8, 7]
        assert (adc.lo, adc.hi) == (-8, 7)
        # single row, x slice value 1 -> cs == plane value, exactly
        planes = np.zeros((1, 1, 512, 4), np.int8)
        planes[0, 0, 0] = [7, -8, 6, -7]  # hi, lo, hi-1, lo+1
        centers = np.zeros((1, 4), np.int32)
        x = jnp.ones((1, 1), jnp.int32)
        psum, sats = ops.fused_crossbar_forward(
            x, jnp.asarray(planes), (0,), jnp.asarray(centers),
            input_slicing=(8,), adc_lo=adc.lo, adc_hi=adc.hi,
            backend=backend)
        np.testing.assert_array_equal(np.asarray(psum),
                                      [[7, -8, 6, -7]])
        assert int(sats) == 2

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_saturating_segment_boundary(self, backend):
        """All-maximal inputs and weights: each segment (512 rows + the
        188-row ragged tail) must clamp independently."""
        w_u = np.full((700, 4), 255, np.int64)
        enc = co.encode(w_u, (4, 2, 2), mode="zero")  # residuals +127
        x = jnp.full((2, 700), 255, jnp.int32)
        got, st_f = xbar.forward(x, enc, (4, 2, 2), backend=backend)
        want, st_py = xbar.forward(x, enc, (4, 2, 2), backend="python")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(st_f.saturations) == int(st_py.saturations) > 0


class TestAccounting:
    """The fused path's counters must match the Python crossbar counters
    exactly on pinned shapes — ``core.energy`` and
    ``CompiledPim.report()`` price designs off these numbers."""

    @pytest.mark.parametrize("rows,cols,B,i_slicing", [
        (512, 16, 8, (1,) * 8),
        (700, 12, 5, (4, 2, 2)),
        (130, 3, 2, (2, 2, 2, 2)),
    ])
    def test_counters_match_python(self, rows, cols, B, i_slicing):
        rng = np.random.default_rng(rows + cols + B)
        # wide weights + real 7b ADC so saturations are plentiful
        w_u = np.clip(rng.normal(128, 70, (rows, cols)), 0, 255).astype(np.int64)
        enc = co.encode(w_u, (4, 2, 2))
        x = jnp.asarray(rng.integers(0, 256, (B, rows)))
        _, st_py = xbar.forward(x, enc, i_slicing, backend="python")
        for backend in BACKENDS:
            _, st_f = xbar.forward(x, enc, i_slicing, backend=backend)
            assert int(st_f.adc_converts) == int(st_py.adc_converts)
            assert int(st_f.conversions_possible) == \
                int(st_py.conversions_possible)
            assert int(st_f.saturations) == int(st_py.saturations)
            assert st_f.macs == st_py.macs

    def test_unsigned_adc_counters(self):
        """ISAAC-style unsigned window: 0 sits on the lo bound, so even
        zero sums count as saturated — both paths must agree on that."""
        rng = np.random.default_rng(9)
        w_u = rng.integers(0, 256, (256, 8)).astype(np.int64)
        enc = co.encode(w_u, (4, 4), mode="unsigned")
        x = jnp.asarray(rng.integers(0, 256, (3, 256)))
        _, st_py = xbar.forward(x, enc, (4, 4), adc_lib.ISAAC_ADC,
                                backend="python")
        for backend in BACKENDS:
            psum_f, st_f = xbar.forward(x, enc, (4, 4), adc_lib.ISAAC_ADC,
                                        backend=backend)
            assert int(st_f.saturations) == int(st_py.saturations)


class TestAdcZeroPoint:
    """Satellite: the padding contract is now an explicit invariant."""

    def test_zero_point_shifts_window(self):
        cfg = adc_lib.ADCConfig(bits=7, signed=True, zero_point=10)
        assert (cfg.lo, cfg.hi) == (-54, 73)
        assert cfg.zero_preserving

    def test_misconfigured_zero_point_breaks_zero(self):
        """A window excluding 0 maps analog 0 to a non-zero code — the
        hazard the invariant guards against."""
        bad = adc_lib.ADCConfig(bits=4, signed=True, zero_point=20)
        assert bad.lo > 0 and not bad.zero_preserving
        assert int(np.clip(0, bad.lo, bad.hi)) != 0

    def test_convert_refuses(self):
        bad = adc_lib.ADCConfig(bits=4, signed=True, zero_point=20)
        with pytest.raises(ValueError, match="padding contract"):
            adc_lib.convert(jnp.zeros((4,), jnp.int32), bad)

    @pytest.mark.parametrize("backend", ["python", "xla"])
    def test_crossbar_forward_refuses(self, backend):
        rng = np.random.default_rng(11)
        _, enc, x = _mk_layer(rng, 64, 4, 2, (4, 4))
        bad = adc_lib.ADCConfig(bits=7, signed=True, zero_point=100)
        with pytest.raises(ValueError, match="padding contract"):
            xbar.forward(x, enc, (4, 4), bad, backend=backend)

    def test_good_windows_pass(self):
        for cfg in (adc_lib.RAELLA_ADC, adc_lib.ISAAC_ADC,
                    adc_lib.ADCConfig(bits=5, signed=True, zero_point=-3)):
            adc_lib.check_zero_preserving(cfg)  # no raise


class TestBackendRegistry:
    def test_registered_ops_and_backends(self):
        for op in ("centered_int8_matmul", "sliced_crossbar_matmul",
                   "fused_crossbar"):
            assert set(ops.backends(op)) == \
                {"xla", "interpret", "pallas-tpu"}

    def test_resolution_order(self, monkeypatch):
        # CI's kernels-interpret leg pins the env override; the
        # resolution-order contract below is about the un-overridden path
        monkeypatch.delenv(ops.ENV_VAR, raising=False)
        assert ops.resolve_backend("fused_crossbar", "xla") == "xla"
        assert ops.resolve_backend("fused_crossbar", "interpret") == \
            "interpret"
        # auto on the CPU test host -> the XLA reference
        assert ops.resolve_backend("fused_crossbar") == "xla"
        assert ops.resolve_backend("fused_crossbar", "auto") == "xla"
        # 'pallas' alias: interpret off-TPU (legacy use_pallas semantics)
        assert ops.resolve_backend("fused_crossbar", "pallas") == "interpret"
        # unregistered backend falls back to the XLA reference
        assert ops.resolve_backend("fused_crossbar", "pallas-gpu") == "xla"

    def test_env_override_wins(self):
        prev = os.environ.get(ops.ENV_VAR)
        os.environ[ops.ENV_VAR] = "interpret"
        try:
            assert ops.resolve_backend("fused_crossbar", "xla") == "interpret"
        finally:
            if prev is None:
                del os.environ[ops.ENV_VAR]
            else:
                os.environ[ops.ENV_VAR] = prev

    def test_unknown_names_raise(self, monkeypatch):
        monkeypatch.delenv(ops.ENV_VAR, raising=False)
        with pytest.raises(KeyError):
            ops.resolve_backend("no_such_op")
        with pytest.raises(ValueError):
            ops.resolve_backend("fused_crossbar", "triton")

    def test_blocked_kernel_matches_defaults(self):
        """Non-default tile sizes hit the revisit/accumulate logic."""
        rng = np.random.default_rng(21)
        x = jnp.asarray(rng.integers(0, 256, (20, 600)), jnp.int32)
        w = jnp.asarray(rng.integers(-15, 16, (2, 1024, 300)), jnp.int8)
        in_li = jnp.asarray([4, 0], jnp.int32)
        in_mask = jnp.asarray([15, 15], jnp.int32)
        mults = jnp.asarray([[16, 1], [256, 16]], jnp.int32)
        cen = jnp.asarray(rng.integers(1, 256, (2, 300)), jnp.int32)
        want, wsat = ref.fused_crossbar(x, w, in_li, in_mask, mults, cen)
        for bm, bn in [(8, 128), (16, 256)]:
            got, gsat = fx.fused_crossbar(
                x, w, in_li, in_mask, mults, cen, bm=bm, bn=bn,
                interpret=True)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            assert int(gsat) == int(wsat)


class TestEndToEndExactPath:
    """The wired dispatch: ``pim_linear.forward_exact`` (signed inputs,
    two unsigned passes, dequant) is bit-identical across kernel
    backends, so exact-mode prefill/decode runs at kernel speed without
    changing a single logit."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_forward_exact_bitwise(self, backend):
        rng = np.random.default_rng(31)
        w = jnp.asarray(rng.normal(0, 0.05, (300, 16)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 0.5, (4, 300)), jnp.float32)
        plan = pim_linear.prepare(w, x, weight_slicing=(4, 2, 2),
                                  speculation=False)
        y_py = pim_linear.forward_exact(
            x, dataclasses.replace(plan, kernel_backend="python"))
        y_be = pim_linear.forward_exact(
            x, dataclasses.replace(plan, kernel_backend=backend))
        np.testing.assert_array_equal(np.asarray(y_py), np.asarray(y_be))

    def test_forward_exact_under_jit(self):
        """The fused op must trace cleanly inside jit (the models call it
        from scanned/jitted forwards)."""
        rng = np.random.default_rng(33)
        w = jnp.asarray(rng.normal(0, 0.05, (130, 8)), jnp.float32)
        x = jnp.asarray(np.maximum(rng.normal(0.2, 0.3, (3, 130)), 0),
                        jnp.float32)
        plan = pim_linear.prepare(w, x, weight_slicing=(4, 4),
                                  speculation=False)
        plan = dataclasses.replace(plan, kernel_backend="interpret")
        eager = pim_linear.forward_exact(x, plan)
        jitted = jax.jit(lambda xi: pim_linear.forward_exact(xi, plan))(x)
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
