"""Training substrate: optimizer, loop, checkpoint/restart, fault tolerance,
gradient compression, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt
from repro.train import train_loop as tl

CFG = configs.get("qwen1.5-0.5b").reduced(vocab_size=64)
OPT = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                      weight_decay=0.01)


def _data(start=0):
    return SyntheticLM(vocab_size=64, seq_len=32, batch_size=8,
                       seed=7).iterator(start)


class TestOptimizer:
    def test_lr_schedule(self):
        assert float(opt.lr_at(OPT, jnp.asarray(0))) == 0.0
        assert float(opt.lr_at(OPT, jnp.asarray(5))) == pytest.approx(OPT.lr)
        assert float(opt.lr_at(OPT, jnp.asarray(200))) < 1e-4

    def test_clip(self):
        tree = {"a": jnp.full((10,), 100.0)}
        clipped, gn = opt.clip_by_global_norm(tree, 1.0)
        assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-3)

    def test_apply_updates_moves_params(self):
        params, _ = T.init_params(CFG, jax.random.key(0))
        state = opt.init_state(OPT, params)
        grads = jax.tree.map(jnp.ones_like, params)
        newp, news, m = opt.apply_updates(OPT, params, grads, state)
        assert int(news["step"]) == 1
        diff = opt.global_norm(jax.tree.map(lambda a, b: a - b, params, newp))
        assert float(diff) > 0

    def test_bf16_state_dtype(self):
        cfgb = opt.AdamWConfig(state_dtype="bfloat16")
        params, _ = T.init_params(CFG, jax.random.key(0))
        state = opt.init_state(cfgb, params)
        assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state["m"]))


class TestTrainLoop:
    def test_loss_decreases(self):
        data = SyntheticLM(vocab_size=64, seq_len=32, batch_size=8, seed=7)
        state = tl.train(CFG, OPT, data.iterator(0), num_steps=30,
                         log_every=0)
        first = T.lm_loss(state.params, CFG, data.batch(1000))
        # untrained reference
        p0, _ = T.init_params(CFG, jax.random.key(1))
        ref = T.lm_loss(p0, CFG, data.batch(1000))
        assert float(first) < float(ref) - 0.3

    def test_microbatched_step_matches_full(self):
        """Gradient accumulation must match the monolithic step closely."""
        import dataclasses as dc
        cfg1 = CFG
        cfg2 = dc.replace(CFG, micro_batches=4)
        params, _ = T.init_params(cfg1, jax.random.key(0))
        ostate = opt.init_state(OPT, params)
        batch = SyntheticLM(64, 32, 8, seed=3).batch(0)
        s1 = tl.make_train_step(cfg1, OPT)
        s2 = tl.make_train_step(cfg2, OPT)
        p1, _, m1 = jax.jit(s1)(params, ostate, batch)
        p2, _, m2 = jax.jit(s2)(params, ostate, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-2)
        d = opt.global_norm(jax.tree.map(lambda a, b: a - b, p1, p2))
        n = opt.global_norm(p1)
        assert float(d) / float(n) < 1e-2


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10.0), "b": [jnp.ones((3, 4), jnp.bfloat16)]}
        ckpt.save(str(tmp_path), 7, tree)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        got, step, _ = ckpt.restore(str(tmp_path), like)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(10.0))
        assert got["b"][0].dtype == jnp.bfloat16

    def test_atomic_no_partial(self, tmp_path):
        tree = {"a": jnp.zeros(4)}
        ckpt.save(str(tmp_path), 1, tree)
        # a stale .tmp dir must be ignored
        os.makedirs(tmp_path / "step_000000009.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_gc_keeps_three(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        for s in range(6):
            ckpt.save(str(tmp_path), s, tree)
        kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
        assert len(kept) == 3

    def test_async_checkpointer(self, tmp_path):
        tree = {"a": jnp.arange(5.0)}
        ac = ckpt.AsyncCheckpointer(str(tmp_path))
        ac.save(3, tree)
        ac.wait()
        assert ckpt.latest_step(str(tmp_path)) == 3


class TestFaultTolerance:
    def test_straggler_monitor(self):
        mon = ft.StragglerMonitor(threshold=2.0, min_samples=3)
        for i in range(5):
            assert not mon.observe(i, 0.1)
        assert mon.observe(5, 0.5)
        assert len(mon.events) == 1

    def test_elastic_axis(self):
        assert ft.elastic_data_axis(512, 16) == 32
        assert ft.elastic_data_axis(480, 16) == 30  # lost a host
        with pytest.raises(ValueError):
            ft.elastic_data_axis(8, 16)

    def test_restart_from_failure(self, tmp_path):
        """Inject a crash mid-run; training must resume from the checkpoint
        and reach the target step with the same final state structure."""
        crashed = {"done": False}

        def injector(step):
            if step == 12 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected node failure")

        data_fn = lambda start: SyntheticLM(
            vocab_size=64, seq_len=32, batch_size=8, seed=7).iterator(start)
        state = ft.resilient_train(
            CFG, OPT, data_fn, num_steps=20, ckpt_dir=str(tmp_path),
            ckpt_every=5, fail_injector=injector)
        assert state.step == 20
        assert crashed["done"]
        assert ckpt.latest_step(str(tmp_path)) == 20


class TestCompression:
    def test_quantize_roundtrip_error_small(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1e-3, size=(1000,)), jnp.float32)
        q, s = comp.quantize_int8(x)
        deq = comp.dequantize_int8(q, s, x.shape, x.dtype)
        rel = float(jnp.linalg.norm(deq - x) / jnp.linalg.norm(x))
        assert rel < 0.01

    def test_error_feedback_converges(self):
        """Repeatedly compressing the same gradient with EF must pass the
        full value through on average (bias-free)."""
        x = jnp.asarray([1e-4, -2e-4, 3e-4] * 100, jnp.float32)
        err = jnp.zeros_like(x)
        acc = jnp.zeros_like(x)
        for _ in range(50):
            deq, err = comp.compress_decompress(x, err)
            acc = acc + deq
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(x),
                                   rtol=0.02, atol=1e-7)

    def test_compressed_psum_multidevice(self):
        if jax.device_count() < 2:
            pytest.skip("needs >1 device")
        mesh = jax.make_mesh((jax.device_count(),), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        tree = {"g": jnp.ones((comp.CHUNK * 2,), jnp.float32) * 0.5}
        mean, err = comp.compressed_psum(tree, mesh, "data")
        np.testing.assert_allclose(np.asarray(mean["g"]), 0.5, rtol=0.02)


class TestServeEngine:
    def test_greedy_matches_forward_argmax(self):
        from repro.serve.engine import ServeEngine
        cfg = configs.get("yi-6b").reduced()
        params, _ = T.init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, max_len=32)
        prompts = np.asarray(
            jax.random.randint(jax.random.key(1), (2, 5), 0, cfg.vocab_size))
        res = eng.generate(prompts, steps=4)
        assert res.tokens.shape == (2, 4)
        # first generated token == argmax of forward at last prompt position
        full = T.forward(params, cfg, jnp.asarray(prompts))
        want = np.asarray(jnp.argmax(full[:, -1], axis=-1))
        np.testing.assert_array_equal(res.tokens[:, 0], want)

    def test_encoder_rejects(self):
        from repro.serve.engine import ServeEngine
        cfg = configs.get("hubert-xlarge").reduced()
        params, _ = T.init_params(cfg, jax.random.key(0))
        with pytest.raises(ValueError):
            ServeEngine(cfg, params)
