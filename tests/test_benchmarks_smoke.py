"""Every ``benchmarks/run.py`` registry entry runs at toy size, returns
JSON-serializable output, and its headline formatter works on that
output — so the benchmark surface cannot silently rot (CI: the
``benchmarks-smoke`` job)."""

import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:  # `benchmarks` package lives at the repo root
    sys.path.insert(0, str(ROOT))

from benchmarks.run import REGISTRY  # noqa: E402


def test_registry_covers_expected_entries():
    for name in ("lm_on_pim", "serve_pim", "serve_continuous",
                 "compile_report", "fig15_corners", "table4_corners"):
        assert name in REGISTRY


def test_corner_entries_point_at_device_corner_sweeps():
    for name in ("fig15_corners", "table4_corners"):
        assert REGISTRY[name].attr == "run_device_corners"
        assert "corners" in REGISTRY[name].smoke_kwargs


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_entry_runs_and_serializes(name):
    entry = REGISTRY[name]
    out = entry.run(**entry.smoke_kwargs)
    json.dumps(out)  # contract: plain python scalars/lists/dicts only
    assert isinstance(entry.derive(out), str)
