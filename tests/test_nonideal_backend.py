"""Statistical differential tests for the device-realism crossbar backends.

Locks down ``repro.core.backends``:

  - the zero-corner contract: a ``NonidealSim`` with all-zero magnitudes
    is bit-exact with ``IdealSim`` AND with the fused kernel path, on the
    xla and interpret kernel backends, including through
    ``pim_linear.forward_exact`` under jit;
  - seeded determinism: the same die key programs the identical die,
    under jit and vmap; different die seeds differ;
  - statistics: output error grows monotonically in each nonideality
    magnitude; stuck-at fault counts match the configured Bernoulli rate
    within binomial bounds; padding rows/planes never grow devices;
  - accounting: ``CrossbarStats`` work counters are invariant to the
    device model (nonidealities change values, never convert counts).
"""

import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import adc as adc_lib
from repro.core import backends as bk
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import pim_linear as plin

LOSSLESS_ADC = adc_lib.ADCConfig(bits=24, signed=True)


def _planes(rng, n_w=2, n_seg=1, R=96, C=6):
    return jnp.asarray(
        rng.integers(-127, 128, size=(n_w, n_seg, R, C), dtype=np.int64),
        jnp.int32)


def _zero_die(seed=0):
    return bk.NonidealSim(corner=bk.DeviceCorner(), key=jax.random.key(seed))


def _layer(rng, rows, cols):
    w_signed = np.clip(rng.normal(0, 20, size=(rows, cols)), -127, 127)
    w_u = (np.round(w_signed) + 128).astype(np.int64)
    x = jnp.asarray(rng.integers(0, 256, size=(4, rows)))
    return w_u, x


# ------------------------------------------------------------ registry
class TestRegistry:
    def test_make_ideal_is_singleton(self):
        assert bk.make("ideal") is bk.IDEAL

    def test_make_nonideal_carries_corner_and_seed(self):
        dev = bk.make("nonideal", "3sigma", seed=5)
        assert isinstance(dev, bk.NonidealSim)
        assert dev.corner == bk.SIGMA3
        assert dev.name == "nonideal"

    def test_unknown_backend_and_corner_raise(self):
        with pytest.raises(ValueError, match="crossbar backend"):
            bk.make("analog-dreams")
        with pytest.raises(ValueError, match="device corner"):
            bk.corner("9sigma")

    def test_named_corners_are_ordered_nominal_first(self):
        names = list(bk.CORNERS)
        assert names[0] == "nominal"
        assert bk.CORNERS["nominal"] == bk.DeviceCorner()

    def test_archconfig_accepts_every_named_corner(self):
        # configs.base hardcodes the corner-name tuple (it must not import
        # core); this is the sync test that keeps it equal to CORNERS.
        cfg = configs.get("yi-6b")
        for name in bk.CORNERS:
            dataclasses.replace(cfg, pim_crossbar_backend="nonideal",
                                pim_device_corner=name)
        with pytest.raises(ValueError, match="pim_device_corner"):
            dataclasses.replace(cfg, pim_device_corner="9sigma")
        with pytest.raises(ValueError, match="pim_crossbar_backend"):
            dataclasses.replace(cfg, pim_crossbar_backend="analog-dreams")

    def test_corner_is_a_pytree(self):
        leaves = jax.tree.leaves(bk.SIGMA3)
        assert len(leaves) == 6

    def test_stack_corners_shapes(self):
        stacked = bk.stack_corners([bk.NOMINAL, bk.SIGMA1, bk.SIGMA3])
        assert stacked.program_sigma.shape == (3,)
        np.testing.assert_allclose(
            np.asarray(stacked.stuck_rate), [0.0, 1e-3, 5e-3])


# --------------------------------------------------- zero-corner contract
class TestZeroCorner:
    """All-zero magnitudes must be BIT-exact with the ideal integer sim."""

    def test_program_read_bit_exact(self):
        rng = np.random.default_rng(0)
        planes = _planes(rng)
        x = jnp.asarray(rng.integers(0, 256, size=(4, 1, 96)), jnp.int32)
        ideal_prog = bk.IDEAL.program(planes, rows=96)
        zero_prog = _zero_die().program(planes, rows=96)
        np.testing.assert_array_equal(np.asarray(zero_prog.gp),
                                      np.asarray(ideal_prog.gp))
        np.testing.assert_array_equal(np.asarray(zero_prog.gn),
                                      np.asarray(ideal_prog.gn))
        assert not np.asarray(zero_prog.stuck_on).any()
        assert not np.asarray(zero_prog.stuck_off).any()
        for j in range(planes.shape[0]):
            pi = bk.IDEAL.read(ideal_prog, x, j)
            pz = _zero_die().read(zero_prog, x, j)
            np.testing.assert_array_equal(np.asarray(pz[0]), np.asarray(pi[0]))
            np.testing.assert_array_equal(np.asarray(pz[1]), np.asarray(pi[1]))

    @pytest.mark.parametrize("kernel_backend", ["xla", "interpret"])
    def test_forward_matches_fused_kernel(self, kernel_backend):
        rng = np.random.default_rng(1)
        w_u, x = _layer(rng, 64, 4)
        x = x[:2]
        enc = co.encode(w_u, (4, 2, 2))
        fused, _ = xbar.forward(x, enc, (4, 4), backend=kernel_backend)
        loop, _ = xbar.forward(x, enc, (4, 4), backend="python")
        nonid, _ = xbar.forward(x, enc, (4, 4), device=_zero_die())
        np.testing.assert_array_equal(np.asarray(loop), np.asarray(fused))
        np.testing.assert_array_equal(np.asarray(nonid), np.asarray(fused))

    def test_forward_exact_under_jit(self):
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.normal(0, 0.4, size=(128, 8)), jnp.float32)
        x_cal = jnp.asarray(rng.normal(0, 1.0, size=(10, 128)), jnp.float32)
        plan = plin.prepare(w, x_cal, speculation=False, adc=LOSSLESS_ADC)
        plan_zero = dataclasses.replace(plan, device=_zero_die())
        x = jnp.asarray(rng.normal(0, 1.0, size=(4, 128)), jnp.float32)
        y_ideal = jax.jit(lambda a: plin.forward_exact(a, plan))(x)
        y_zero = jax.jit(lambda a: plin.forward_exact(a, plan_zero))(x)
        np.testing.assert_array_equal(np.asarray(y_zero), np.asarray(y_ideal))

    def test_speculation_plan_falls_back_and_stays_exact(self):
        # A nonideal device forces static input slicing; with a lossless
        # ADC the fallback must still reproduce the int8 oracle exactly.
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.normal(0, 0.4, size=(96, 6)), jnp.float32)
        x_cal = jnp.asarray(rng.normal(0, 1.0, size=(10, 96)), jnp.float32)
        plan = plin.prepare(w, x_cal, speculation=True, adc=LOSSLESS_ADC)
        plan_zero = dataclasses.replace(plan, device=_zero_die())
        x = jnp.asarray(rng.normal(0, 1.0, size=(4, 96)), jnp.float32)
        want = plin.forward_int_reference(x, plan)
        got = plin.forward_exact(x, plan_zero)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_padded_slice_planes_stay_inert(self):
        # All-zero padding planes must stay all-zero even on a faulty die
        # (G_on of an empty plane is 0 by construction).
        rng = np.random.default_rng(4)
        planes = _planes(rng, n_w=3)
        planes = planes.at[2].set(0)  # a slice-padding plane
        die = bk.make("nonideal", "3sigma", seed=7)
        prog = die.program(planes, rows=96)
        assert not np.asarray(prog.gp[2]).any()
        assert not np.asarray(prog.gn[2]).any()


# ----------------------------------------------------------- determinism
class TestDeterminism:
    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    @hypothesis.settings(max_examples=6, deadline=None)
    def test_same_die_programs_identically(self, seed):
        rng = np.random.default_rng(123)
        planes = _planes(rng, R=64, C=4)
        die = bk.make("nonideal", "3sigma", seed=seed)
        a = die.program(planes, rows=64)
        a2 = die.program(planes, rows=64)
        jit_prog = jax.jit(lambda p: die.program(p, rows=64))
        b, b2 = jit_prog(planes), jit_prog(planes)
        # bit-identical across calls in each execution mode
        np.testing.assert_array_equal(np.asarray(a.gp), np.asarray(a2.gp))
        np.testing.assert_array_equal(np.asarray(b.gp), np.asarray(b2.gp))
        # jit may fuse the exp chain differently (~1e-7 rel); the fault
        # maps — exact comparisons on identical uniforms — never move
        np.testing.assert_allclose(np.asarray(a.gp), np.asarray(b.gp),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(a.stuck_on),
                                      np.asarray(b.stuck_on))

    def test_different_die_seeds_differ(self):
        rng = np.random.default_rng(5)
        planes = _planes(rng)
        a = bk.make("nonideal", "3sigma", seed=0).program(planes, rows=96)
        b = bk.make("nonideal", "3sigma", seed=1).program(planes, rows=96)
        assert np.abs(np.asarray(a.gp) - np.asarray(b.gp)).max() > 0

    def test_vmap_over_stacked_corners(self):
        rng = np.random.default_rng(6)
        planes = _planes(rng, R=64, C=4)
        stacked = bk.stack_corners([bk.NOMINAL, bk.SIGMA1, bk.SIGMA3])
        key = jax.random.key(0)

        def prog_gp(c):
            return bk.NonidealSim(corner=c, key=key).program(
                planes, rows=64).gp

        gps = jax.vmap(prog_gp)(stacked)
        assert gps.shape == (3,) + planes.shape
        # lane 0 is the nominal die == the ideal magnitudes
        np.testing.assert_array_equal(
            np.asarray(gps[0]),
            np.asarray(bk.IDEAL.program(planes, rows=64).gp))
        # heavier corners move the conductances more
        d1 = np.abs(np.asarray(gps[1]) - np.asarray(gps[0])).mean()
        d3 = np.abs(np.asarray(gps[2]) - np.asarray(gps[0])).mean()
        assert 0.0 < d1 < d3


# ------------------------------------------------------------ statistics
def _read_error(corner_, planes, x, key):
    """Mean |column-sum error| of a die at ``corner_`` vs the ideal read."""
    die = bk.NonidealSim(corner=corner_, key=key)
    prog = die.program(planes, rows=planes.shape[1] * planes.shape[2])
    iprog = bk.IDEAL.program(planes)
    err = 0.0
    for j in range(planes.shape[0]):
        pos, neg = die.read(prog, x, j)
        ipos, ineg = bk.IDEAL.read(iprog, x, j)
        err += float(jnp.abs((pos - neg) - (ipos - ineg)).mean())
    return err


class TestStatistics:
    KNOBS = {
        "program_sigma": [dict(program_sigma=s) for s in (0.01, 0.1, 0.5)],
        "drift": [dict(drift_nu=n, drift_time=1e5)
                  for n in (0.005, 0.03, 0.1)],
        "stuck_rate": [dict(stuck_rate=r) for r in (0.02, 0.1, 0.4)],
        "ir_drop_alpha": [dict(ir_drop_alpha=a) for a in (0.02, 0.1, 0.3)],
    }

    @pytest.mark.parametrize("knob", sorted(KNOBS))
    def test_error_grows_with_magnitude(self, knob):
        rng = np.random.default_rng(7)
        planes = _planes(rng, n_w=2, R=128, C=8)
        x = jnp.asarray(rng.integers(0, 256, size=(8, 1, 128)), jnp.int32)
        key = jax.random.key(11)
        errs = [_read_error(bk.DeviceCorner(**kw), planes, x, key)
                for kw in self.KNOBS[knob]]
        zero = _read_error(bk.DeviceCorner(), planes, x, key)
        assert zero == 0.0
        # magnitudes are ~5-10x apart, so strict growth is robust
        assert 0.0 < errs[0] < errs[1] < errs[2], (knob, errs)

    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    @hypothesis.settings(max_examples=4, deadline=None)
    def test_stuck_counts_within_binomial_bounds(self, seed):
        rng = np.random.default_rng(9)
        n_w, R, C, rate = 2, 256, 8, 0.05
        planes = _planes(rng, n_w=n_w, R=R, C=C)
        die = bk.NonidealSim(corner=bk.DeviceCorner(stuck_rate=rate),
                             key=jax.random.key(seed))
        prog = die.program(planes, rows=R)
        stuck = (np.asarray(prog.stuck_on).sum()
                 + np.asarray(prog.stuck_off).sum())
        n = 2 * n_w * R * C  # Bernoulli draws per device (pos + neg arrays)
        mean, sd = n * rate, np.sqrt(n * rate * (1 - rate))
        assert abs(stuck - mean) < 6 * sd, (stuck, mean, sd)

    def test_stuck_on_frac_splits_faults(self):
        rng = np.random.default_rng(10)
        planes = _planes(rng, R=256, C=8)
        for onf, attr in ((1.0, "stuck_off"), (0.0, "stuck_on")):
            die = bk.NonidealSim(
                corner=bk.DeviceCorner(stuck_rate=0.1, stuck_on_frac=onf),
                key=jax.random.key(2))
            prog = die.program(planes, rows=256)
            assert not np.asarray(getattr(prog, attr)).any()

    def test_no_faults_on_padding_rows(self):
        # rows beyond the true input length hold no physical devices
        rng = np.random.default_rng(11)
        planes = _planes(rng, n_seg=2, R=128, C=4)  # 256 padded rows
        die = bk.NonidealSim(corner=bk.DeviceCorner(stuck_rate=0.5),
                             key=jax.random.key(3))
        prog = die.program(planes, rows=200)
        on = np.asarray(prog.stuck_on)   # (2, n_w, n_seg, R, C)
        off = np.asarray(prog.stuck_off)
        flat = (on.any(0) | off.any(0)).any(axis=(0, 3)).reshape(-1)
        assert flat[:200].any()          # live region does fault at 50%
        assert not flat[200:].any()      # padding never does


# ------------------------------------------------------------ accounting
class TestStatsInvariants:
    def test_work_counters_device_invariant(self):
        rng = np.random.default_rng(12)
        w_u, x = _layer(rng, 96, 6)
        enc = co.encode(w_u, (4, 2, 2))
        _, st_ideal = xbar.forward(x, enc, (4, 4), backend="python")
        _, st_fused = xbar.forward(x, enc, (4, 4))
        _, st_zero = xbar.forward(x, enc, (4, 4), device=_zero_die())
        _, st_die = xbar.forward(
            x, enc, (4, 4), device=bk.make("nonideal", "3sigma", seed=1))
        for st_ in (st_fused, st_zero, st_die):
            assert int(st_.adc_converts) == int(st_ideal.adc_converts)
            assert int(st_.conversions_possible) == \
                int(st_ideal.conversions_possible)
            assert st_.macs == st_ideal.macs
        # the zero corner also reproduces the saturation count bit-exactly
        assert int(st_zero.saturations) == int(st_ideal.saturations)
