"""End-to-end training driver: train a ~100M-param qwen1.5-0.5b-family model
on the synthetic Markov LM for a few hundred steps with checkpointing and
straggler monitoring.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--full-100m]

Default runs a CPU-sized model so the example finishes in ~2 minutes; with
--full-100m it builds the ~100M-parameter variant (slow on CPU; sized for a
single accelerator host).
"""

import argparse
import dataclasses

import jax

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as T
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt
from repro.train import train_loop as tl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    base = configs.get("qwen1.5-0.5b")
    if args.full_100m:
        cfg = dataclasses.replace(
            base, name="qwen-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=12, d_ff=2048, vocab_size=8192, head_dim=64,
            remat=False)
    else:
        cfg = base.reduced(n_layers=4, d_model=128, d_ff=256, vocab_size=256,
                           n_heads=4, n_kv_heads=4, head_dim=32)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128, batch_size=16,
                       seed=0, concentration=0.1)
    print(f"synthetic LM entropy floor: {data.entropy_floor():.3f} nats")
    opt_cfg = opt.AdamWConfig(lr=2e-2, warmup_steps=20,
                              total_steps=args.steps)
    mon = ft.StragglerMonitor()
    state = tl.train(cfg, opt_cfg, data.iterator(0), num_steps=args.steps,
                     hooks=[mon.hook()], log_every=25)
    final = T.lm_loss(state.params, cfg, data.batch(10_000))
    print(f"final eval loss {float(final):.3f} "
          f"(uniform {float(jax.numpy.log(cfg.vocab_size)):.3f}, "
          f"floor ~{data.entropy_floor():.3f}); "
          f"stragglers flagged: {len(mon.events)}")


if __name__ == "__main__":
    main()
