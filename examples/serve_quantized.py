"""Serve a small LM with batched requests, comparing the bf16 path against
the RAELLA fast path (centered int8, Eq. 1) on the same prompts.

  PYTHONPATH=src python examples/serve_quantized.py
"""


import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import pim_linear as plin
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def main() -> None:
    cfg = configs.get("yi-6b").reduced(d_model=128, d_ff=256, n_layers=2,
                                       vocab_size=512, n_heads=4,
                                       n_kv_heads=2, head_dim=32)
    params, _ = T.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = np.asarray(jax.random.randint(jax.random.key(1), (8, 8), 0,
                                            cfg.vocab_size))
    res = eng.generate(prompts, steps=16)
    print(f"bf16 engine: {res.tokens.shape} tokens for 8 requests")

    # RAELLA fast path on the LM head (the big static matmul at decode):
    head = params["embed"]["head"].astype(jnp.float32)
    x = jax.random.normal(jax.random.key(2), (32, cfg.d_model))
    plan = plin.prepare(head, x, speculation=False)
    y_ref = x @ head
    y_fast = plin.forward_fast(x, plan, use_pallas=True)
    rel = float(jnp.linalg.norm(y_fast - y_ref) / jnp.linalg.norm(y_ref))
    print(f"centered-int8 LM head (Pallas): rel err {rel:.4f} vs bf16; "
          f"weights stored int8 = 2x HBM traffic saved at decode")


if __name__ == "__main__":
    main()
