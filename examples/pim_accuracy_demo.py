"""Center+Offset vs Zero+Offset on a trained classifier (Table 4 mechanism).

  PYTHONPATH=src:. python examples/pim_accuracy_demo.py
"""

from benchmarks.table4_accuracy import run


def main() -> None:
    for k, v in run().items():
        print(k, v)


if __name__ == "__main__":
    main()
