"""Quickstart: RAELLA's arithmetic on one layer, end to end.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline on a single linear layer: 8b quantization,
Center+Offset encoding (Eq. 1/2), adaptive weight slicing (Algorithm 1),
speculative crossbar execution with a 7b ADC, and the TPU-native centered
int8 fast path — comparing all of them against the float reference.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import adaptive, pim_linear as plin
from repro.core import energy as en, workloads as wl


def main() -> None:
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0.01, 0.04, (512, 64)), jnp.float32)
    x = jnp.asarray(np.maximum(rng.normal(0.2, 0.4, (10, 512)), 0),
                    jnp.float32)
    y_ref = x @ w

    print("=== Algorithm 1: adaptive weight slicing ===")
    choice = adaptive.find_best_slicing(w, x, error_budget=0.09)
    print(f"chose {choice.slicing} ({choice.n_slices} slices/weight), "
          f"measured error {choice.error:.4f} (budget 0.09)")

    print("\n=== bit-exact accelerator simulation (7b ADC, speculation) ===")
    plan = plin.prepare(w, x, weight_slicing=choice.slicing, speculation=True)
    y_pim, stats = plin.forward_exact(x, plan, return_stats=True)
    rel = float(jnp.linalg.norm(y_pim - y_ref) / jnp.linalg.norm(y_ref))
    st = stats[0]
    print(f"rel error vs float: {rel:.4f}")
    print(f"ADC converts {int(st.adc_converts)} vs recovery-only "
          f"{int(st.no_spec_converts)} "
          f"({1 - int(st.adc_converts)/int(st.no_spec_converts):.0%} saved), "
          f"speculation failure rate {float(st.failure_rate):.1%}")

    print("\n=== TPU-native fast path (Eq. 1 as centered int8 matmul) ===")
    y_fast = plin.forward_fast(x, plan, use_pallas=True)
    rel = float(jnp.linalg.norm(y_fast - y_ref) / jnp.linalg.norm(y_ref))
    print(f"rel error vs float: {rel:.4f} (Pallas kernel, interpret mode)")

    print("\n=== Titanium Law: ResNet18 on RAELLA vs 8b ISAAC ===")
    layers = wl.resnet18()
    ri = en.analyze_dnn(en.ISAAC_8B, layers)
    rr = en.analyze_dnn(en.RAELLA, layers)
    print(f"converts/MAC {ri.converts_per_mac:.3f} -> "
          f"{rr.converts_per_mac:.3f}; energy {ri.energy/rr.energy:.1f}x "
          f"better, throughput {ri.latency_ns/rr.latency_ns:.1f}x")


if __name__ == "__main__":
    main()
