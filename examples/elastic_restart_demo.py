"""Fault tolerance demo: inject a failure mid-training, watch the job
restore from the last async checkpoint and finish.

  PYTHONPATH=src python examples/elastic_restart_demo.py
"""

import tempfile

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt


def main() -> None:
    cfg = configs.get("qwen1.5-0.5b").reduced(vocab_size=128)
    opt_cfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    crashed = {"done": False}

    def injector(step):
        if step == 25 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected: host 7 lost")

    def data(start):
        return SyntheticLM(vocab_size=128, seq_len=32, batch_size=8,
                           seed=3).iterator(start)

    with tempfile.TemporaryDirectory() as d:
        state = ft.resilient_train(cfg, opt_cfg, data, num_steps=50,
                                   ckpt_dir=d, ckpt_every=10,
                                   fail_injector=injector)
    print(f"survived injected failure; finished at step {state.step}")


if __name__ == "__main__":
    main()
