"""Fig. 3: column-sum distribution reshaping across RAELLA's strategies.

Reports, for each pipeline stage, the fraction of column sums representable
in the 7b ADC range and the resolution needed for the 99.9th percentile —
reproducing the 17b -> 7b narrative (paper: <=7b rates 59.2% / 82.1% /
98-99.9% and final saturation ~0.1%)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import realistic_layer
from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import crossbar as xbar
from repro.core import speculation as spec


def run() -> dict:
    rng = np.random.default_rng(0)
    w_u, x = realistic_layer(rng, rows=512, cols=64)
    adc = adc_lib.RAELLA_ADC
    out = {}

    def stats(enc, input_slicing):
        cs, in_range = xbar.column_sum_distribution(x, enc, input_slicing, adc)
        csn = np.asarray(cs, np.int64)
        p999 = np.percentile(np.abs(csn), 99.9)
        bits = int(np.ceil(np.log2(max(p999, 1) + 1))) + 1
        return float(in_range), bits

    # stage 0: baseline — unsigned weights, 4b input x 4b weight slices
    enc0 = co.encode(w_u, (4, 4), mode="unsigned")
    r0, b0 = stats(enc0, (4, 4))
    out["baseline_unsigned_4b"] = {"le7b": r0, "p999_bits": b0}

    # stage 1: + Center+Offset (signed 2T2R, centered)
    enc1 = co.encode(w_u, (4, 4), mode="center")
    r1, b1 = stats(enc1, (4, 4))
    out["center_offset"] = {"le7b": r1, "p999_bits": b1}

    # stage 2: + Adaptive Weight Slicing (4b-2b-2b typical outcome)
    enc2 = co.encode(w_u, (4, 2, 2), mode="center")
    r2, b2 = stats(enc2, (4, 4))
    out["adaptive_slicing"] = {"le7b": r2, "p999_bits": b2}

    # stage 3: + Dynamic Input Slicing — speculation (4-2-2) and recovery (1b)
    r3s, b3s = stats(enc2, (4, 2, 2))
    r3r, b3r = stats(enc2, (1,) * 8)
    out["speculation_cycles"] = {"le7b": r3s, "p999_bits": b3s}
    out["recovery_cycles"] = {"le7b": r3r, "p999_bits": b3r}

    # end-to-end saturation rate with everything on
    _, st = spec.forward(x, enc2)
    out["final_saturation_rate"] = float(st.failure_rate)
    assert r0 < r1 < r2 <= r3r, "pipeline must monotonically tighten sums"
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
