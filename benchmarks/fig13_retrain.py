"""Fig. 13: RAELLA vs retraining architectures (FORMS-8, TIMELY).

FORMS-8: fine-grained polarized pruning (2x MACs reduction on ResNet-class
nets per the paper) + 5b ADC, modeled with halved filter lengths. TIMELY:
its published ~10x efficiency is vs the *original 16b* ISAAC; our baseline
is the paper's 8b-modified ISAAC (~4x better than original), so TIMELY's
efficiency vs ISAAC-8b is ~10/4 = 2.5x. RAELLA matches/exceeds both
WITHOUT retraining (geomean over ResNet18/50, as the paper reports)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import energy as en
from repro.core import workloads as wl


def _geo(xs):
    return float(np.exp(np.mean(np.log(xs))))


def run() -> dict:
    out = {}
    e_fo, t_fo, e_ra, t_ra = [], [], [], []
    for fn in (wl.resnet18, wl.resnet50):
        layers = fn()
        pruned = [dataclasses.replace(
            l, filter_len=max(1, l.filter_len // int(en.FORMS_PRUNE_RATIO)))
            for l in layers]
        ri = en.analyze_dnn(en.ISAAC_8B, layers)
        rf = en.analyze_dnn(en.FORMS_8, pruned)
        rr = en.analyze_dnn(en.RAELLA, layers)
        e_fo.append(ri.energy / rf.energy)
        t_fo.append(ri.latency_ns / rf.latency_ns)
        e_ra.append(ri.energy / rr.energy)
        t_ra.append(ri.latency_ns / rr.latency_ns)
    out["forms8_vs_isaac"] = {"efficiency_x": _geo(e_fo),
                              "throughput_x": _geo(t_fo), "retrains": True}
    out["timely_vs_isaac"] = {
        "efficiency_x": en.TIMELY_REL_EFFICIENCY / 4.0,  # vs 8b baseline
        "retrains": True,
        "note": "published 10x is vs original 16b ISAAC; 8b-ISAAC is ~4x that"}
    out["raella_vs_isaac"] = {"efficiency_x": _geo(e_ra),
                              "throughput_x": _geo(t_ra), "retrains": False}
    out["claim"] = ("RAELLA efficiency >= both retraining architectures "
                    "and throughput ~ FORMS, with no retraining: "
                    f"{_geo(e_ra):.2f}x vs FORMS {_geo(e_fo):.2f}x / "
                    f"TIMELY {out['timely_vs_isaac']['efficiency_x']:.2f}x")
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
