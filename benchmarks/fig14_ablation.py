"""Fig. 14: energy ablation — strategies applied cumulatively.

Paper converts/MAC sequence: 0.25 -> 0.063 -> 0.047 -> 0.018 (ideal), with
ADC dominating ISAAC and each strategy cutting a specific component."""

from __future__ import annotations

from repro.core import energy as en
from repro.core import workloads as wl


def run() -> dict:
    seq = [en.ISAAC_8B, en.CENTER_OFFSET_ONLY, en.CENTER_ADAPTIVE, en.RAELLA]
    layers = wl.resnet18()
    out = {}
    base = None
    for arch in seq:
        rep = en.analyze_dnn(arch, layers, replicate=False)
        ideal_cpm = (arch.n_weight_slices * arch.converts_per_column_pass()
                     / arch.rows)
        if arch.adaptive_slicing:
            ideal_cpm = 3 * arch.converts_per_column_pass() / arch.rows
        e = rep.energy
        base = base or e
        out[arch.name] = {
            "ideal_converts_per_mac": round(ideal_cpm, 4),
            "measured_converts_per_mac": round(rep.converts_per_mac, 4),
            "energy_vs_isaac": round(base / e, 2),
            "adc_share": round(rep.energy_breakdown["e_adc"] / e, 3),
        }
    vals = [v["ideal_converts_per_mac"] for v in out.values()]
    assert vals == sorted(vals, reverse=True)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
