"""Shared harness for the paper benchmarks: realistic synthetic layers and a
small trained classifier for end-to-end accuracy experiments."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adc as adc_lib
from repro.core import center_offset as co
from repro.core import pim_linear as plin


def realistic_layer(rng, rows=512, cols=64, w_scale=12.0, in_mean=12.0,
                    in_sparsity=0.4, skew=0.0):
    """DNN-like integer layer: peaked (Laplacian) weights, sparse
    right-skewed unsigned inputs (paper Fig. 8 distributions)."""
    w_signed = np.clip(rng.laplace(skew, w_scale, size=(rows, cols)), -127, 127)
    w_u = (np.round(w_signed) + 128).astype(np.int64)
    x_raw = rng.exponential(in_mean, size=(16, rows))
    x_raw = x_raw * (rng.random((16, rows)) > in_sparsity)
    x = jnp.asarray(np.clip(x_raw, 0, 255).astype(np.int64))
    return w_u, x


# ------------------------------------------------------------- tiny MLP
@dataclasses.dataclass
class PosTeacher:
    """Teacher task on *positive* inputs (post-ReLU-like activations).

    The bias-free student must encode input means inside its weights, which
    produces the per-channel skewed weight columns of real pretrained nets
    (paper Fig. 5) — the regime where differential (Zero+Offset) encoding
    saturates and Center+Offset does not.
    """
    d_in: int = 128
    n_classes: int = 8
    seed: int = 0

    def __post_init__(self):
        k1, k2 = jax.random.split(jax.random.key(self.seed), 2)
        self.tw1 = jax.random.normal(k1, (self.d_in, 64)) * self.d_in ** -0.5
        self.tw2 = jax.random.normal(k2, (64, self.n_classes)) * 64 ** -0.5

    def batch(self, step: int, n: int):
        k = jax.random.fold_in(jax.random.key(self.seed + 42), step)
        x = jnp.abs(jax.random.normal(k, (n, self.d_in)))
        y = jnp.argmax(
            jnp.maximum((x - x.mean()) @ self.tw1, 0.0) @ self.tw2, -1)
        return x, y


@dataclasses.dataclass
class MLP:
    """Bias-free 2-layer ReLU MLP (weights carry the offsets)."""
    w1: jnp.ndarray
    w2: jnp.ndarray

    def logits(self, x):
        return jnp.maximum(x @ self.w1, 0.0) @ self.w2


@functools.lru_cache(maxsize=4)
def trained_mlp(d_in: int = 128, hidden: int = 256, n_classes: int = 8,
                steps: int = 1500, seed: int = 0):
    """Train the bias-free classifier; returns (mlp, dataset)."""
    ds = PosTeacher(d_in=d_in, n_classes=n_classes, seed=seed)
    k1, k2 = jax.random.split(jax.random.key(seed + 10))
    params = (jax.random.normal(k1, (d_in, hidden)) * d_in ** -0.5,
              jax.random.normal(k2, (hidden, n_classes)) * hidden ** -0.5)

    def loss_fn(p, x, y):
        lg = MLP(*p).logits(x)
        return -jnp.take_along_axis(
            jax.nn.log_softmax(lg), y[:, None], axis=1).mean()

    @jax.jit
    def step(p, x, y, lr):
        g = jax.grad(loss_fn)(p, x, y)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for i in range(steps):
        x, y = ds.batch(i, 256)
        params = step(params, x, y, 0.3 * (0.999 ** i))
    return MLP(*params), ds


def mlp_accuracy(mlp: MLP, ds, n: int = 2048, layer_fn=None) -> float:
    """Accuracy; layer_fn optionally replaces both matmuls (PIM path)."""
    x, y = ds.batch(99991, n)
    if layer_fn is None:
        pred = jnp.argmax(mlp.logits(x), -1)
    else:
        h = jnp.maximum(layer_fn(x, mlp.w1, 0), 0.0)
        lg = layer_fn(h, mlp.w2, 1)
        pred = jnp.argmax(lg, -1)
    return float((pred == y).mean())


def build_pim_plans(mlp: MLP, ds, *, encode_mode="center",
                    weight_slicing=(4, 2, 2), adc=adc_lib.RAELLA_ADC,
                    speculation=True, rows_per_xbar=512) -> dict:
    """Compile both MLP matmuls into PimPlans — the write-once step.

    Returned plans are device-agnostic; hand them to ``plans_layer_fn``
    (any number of times, with different analog array models) to score
    them without re-encoding, mirroring ReRAM's write-once/read-many
    amortization."""
    x_cal, _ = ds.batch(77, 10)  # paper: ten calibration inputs
    h_cal = jnp.maximum(x_cal @ mlp.w1, 0.0)

    def build(w, cal):
        plan = plin.prepare(
            w, cal, weight_slicing=weight_slicing, adc=adc,
            speculation=speculation, encode_mode=encode_mode)
        if rows_per_xbar != 512:
            enc = co.encode(np.asarray(plan.w_q, np.int64) + 128,
                            weight_slicing, mode=encode_mode,
                            rows_per_xbar=rows_per_xbar)
            plan = dataclasses.replace(plan, enc=enc)
        return plan

    return {0: build(mlp.w1, x_cal), 1: build(mlp.w2, h_cal)}


def plans_layer_fn(plans: dict, *, noise_level=0.0, seed=0, device=None):
    """Layer function reading through already-compiled plans.

    ``device`` (a ``repro.core.backends.CrossbarBackend``) swaps the
    analog array model — e.g. a ``NonidealSim`` die corner — without
    touching the compiled encode, so corner sweeps answer "does this
    exact programmed image survive a 3-sigma die?"."""
    if device is not None:
        plans = {i: dataclasses.replace(p, device=device)
                 for i, p in plans.items()}
    key = jax.random.key(seed)

    def layer(x, w, idx):
        return plin.forward_exact(x, plans[idx], noise_level=noise_level,
                                  key=jax.random.fold_in(key, idx))
    return layer


def pim_layer_fn(mlp: MLP, ds, *, encode_mode="center",
                 weight_slicing=(4, 2, 2), adc=adc_lib.RAELLA_ADC,
                 speculation=True, noise_level=0.0, seed=0,
                 rows_per_xbar=512, device=None):
    """Build a layer function running both MLP matmuls through the exact
    accelerator simulation (plans prepared once, reused per call)."""
    plans = build_pim_plans(mlp, ds, encode_mode=encode_mode,
                            weight_slicing=weight_slicing, adc=adc,
                            speculation=speculation,
                            rows_per_xbar=rows_per_xbar)
    return plans_layer_fn(plans, noise_level=noise_level, seed=seed,
                          device=device)
