"""Table 4: Center+Offset vs Zero+Offset fidelity + accuracy, no retraining.

The paper's ImageNet/SQuAD models are unavailable offline, so this
reproduces the mechanism end-to-end on a classifier trained in-repo whose
bias-free weights carry per-channel offsets (the paper's Fig. 5 regime):

  - the §4.2.1 fidelity metric (mean |8b output error| on nonzero outputs),
    where Zero+Offset blows through the 0.09 error budget and Center+Offset
    stays under it;
  - ADC speculation-failure and recovery-saturation rates (the causal chain
    behind Table 4's accuracy drops);
  - end-to-end accuracy. On this small, margin-rich task both encodings
    survive argmax (ReLU masks negative-side saturation); the paper's
    ImageNet compact models (1000 classes, tight margins) lose up to 16.4
    points with Zero+Offset — we quote those alongside.
"""

from __future__ import annotations


from benchmarks.common import (build_pim_plans, mlp_accuracy, pim_layer_fn,
                               plans_layer_fn, trained_mlp)
from repro.core import adaptive, backends
from repro.core import pim_linear as plin

PAPER = {  # (Center+Offset drop, Zero+Offset drop) from the paper's Table 4
    "ResNet18": (0.06, 0.16), "ResNet50": (-0.08, 0.30),
    "MobileNetV2": (0.03, 10.17), "ShuffleNetV2": (0.14, 16.36),
    "GoogLeNet": (-0.02, 1.53), "InceptionV3": (-0.03, 3.72),
    "BERT-Large": (0.12, 0.46),
}


def run(train_steps: int = 1500, eval_n: int = 2048) -> dict:
    mlp, ds = trained_mlp(d_in=512, hidden=512, n_classes=8,
                          steps=train_steps)
    acc_f = mlp_accuracy(mlp, ds, n=eval_n)
    out = {"float_accuracy": acc_f}
    x_cal, _ = ds.batch(77, 10)
    for mode in ["center", "zero"]:
        err = adaptive.measure_error(mlp.w1, x_cal, (4, 2, 2),
                                     encode_mode=mode)
        plan = plin.prepare(mlp.w1, x_cal, weight_slicing=(4, 2, 2),
                            speculation=True, encode_mode=mode)
        _, stats = plin.forward_exact(x_cal, plan, return_stats=True)
        st = stats[0]
        layer = pim_layer_fn(mlp, ds, encode_mode=mode, speculation=True)
        acc = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        out[mode] = {
            "sec4.2.1_error": round(err, 4),
            "under_budget_0.09": err < 0.09,
            "spec_failure_rate": round(float(st.failure_rate), 3),
            "recovery_saturations": int(st.recovery_saturations),
            "accuracy": acc,
            "accuracy_drop_pts": round(100 * (acc_f - acc), 2),
        }
    c, z = out["center"], out["zero"]
    assert c["sec4.2.1_error"] < 0.09, "C+O must satisfy the error budget"
    assert z["sec4.2.1_error"] > 3 * c["sec4.2.1_error"], \
        "Z+O fidelity error must blow up vs C+O (Table 4 mechanism)"
    assert z["spec_failure_rate"] > c["spec_failure_rate"]
    assert c["accuracy_drop_pts"] < 2.0
    out["paper_table4_drops_center_vs_zero"] = PAPER
    return out


def run_device_corners(corners: tuple = ("nominal", "3sigma"),
                       train_steps: int = 1500, eval_n: int = 2048,
                       die_seed: int = 0) -> dict:
    """Table-4 mechanism on nonideal dies, no retraining.

    Both encodings are compiled once (write-once), then each compiled
    image is read through every requested device corner
    (``repro.core.backends.NonidealSim``). Center+Offset's headroom
    argument extends to device variation: the same per-column margins
    that absorb ADC saturation absorb conductance noise, so its corner
    drops stay below Zero+Offset's."""
    mlp, ds = trained_mlp(d_in=512, hidden=512, n_classes=8,
                          steps=train_steps)
    acc_f = mlp_accuracy(mlp, ds, n=eval_n)
    out = {"float_accuracy": acc_f}
    for mode in ["center", "zero"]:
        plans = build_pim_plans(mlp, ds, encode_mode=mode,
                                speculation=False)
        row = {}
        for name in corners:
            dev = backends.make("nonideal", name, seed=die_seed)
            acc = mlp_accuracy(mlp, ds, n=eval_n,
                               layer_fn=plans_layer_fn(plans, device=dev))
            row[name] = {"accuracy": acc,
                         "drop_pts": round(100 * (acc_f - acc), 2)}
        out[mode] = row
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
