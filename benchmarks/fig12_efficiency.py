"""Fig. 12: RAELLA vs 8b-ISAAC energy efficiency + throughput, 7 DNNs.

Paper: efficiency 2.9-4.9x (geomean 3.9x), throughput 0.7-3.3x (geomean
2.0x); without speculation 2.8x / 2.7x geomean."""

from __future__ import annotations

import numpy as np

from repro.core import energy as en
from repro.core import workloads as wl


def _geo(xs):
    return float(np.exp(np.mean(np.log(xs))))


def run() -> dict:
    rows = {}
    es, ts, es_ns, ts_ns = [], [], [], []
    for name, fn in wl.WORKLOADS.items():
        layers = fn()
        ri = en.analyze_dnn(en.ISAAC_8B, layers)
        rr = en.analyze_dnn(en.RAELLA, layers)
        rn = en.analyze_dnn(en.RAELLA_NO_SPEC, layers)
        rows[name] = {
            "efficiency_x": ri.energy / rr.energy,
            "throughput_x": ri.latency_ns / rr.latency_ns,
            "nospec_efficiency_x": ri.energy / rn.energy,
            "nospec_throughput_x": ri.latency_ns / rn.latency_ns,
        }
        es.append(rows[name]["efficiency_x"])
        ts.append(rows[name]["throughput_x"])
        es_ns.append(rows[name]["nospec_efficiency_x"])
        ts_ns.append(rows[name]["nospec_throughput_x"])
    rows["geomean"] = {
        "efficiency_x": _geo(es), "throughput_x": _geo(ts),
        "nospec_efficiency_x": _geo(es_ns), "nospec_throughput_x": _geo(ts_ns),
        "paper": "3.9 / 2.0 (nospec 2.8 / 2.7)",
    }
    return rows


if __name__ == "__main__":
    for k, v in run().items():
        print(k, {kk: (round(vv, 2) if isinstance(vv, float) else vv)
                  for kk, vv in v.items()})
