"""Per-site adaptive-slicing compile report (Algorithm 1 x Titanium Law).

Compiles a reduced architecture with ``pim_weight_slicing="adaptive"`` —
the paper's Algorithm 1 running once per projection site (per repeat, per
MoE expert, conservative 1b-per-slice lm_head) — and prices every site
with the §2.5 energy model: converts/MAC, ADC energy share, and the
slice-count histogram. This is the paper's Fig. 7 ("most layers land on
3 slices, the last layer on 8") and Fig. 12 (ADC energy payoff) story
told for a modern hybrid LM instead of a CNN.

The default arch is the Jamba-style hybrid (mamba + attention + MoE) so
the table exercises every projection family; ``--arch yi-6b`` gives the
small dense version the smoke test runs.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.models import pim_compile
from repro.models import transformer as T


def run(arch: str = "jamba-1.5-large-398b", mode: str = "exact",
        tokens: int = 4096, calib_batch: int = 2, calib_len: int = 8,
        seed: int = 0) -> dict:
    """Compile a reduced ``arch`` adaptively and return the per-site report.

    The compile step itself is simulation-bound (Algorithm 1 measures
    error through the bit-exact crossbar), so this always runs on the
    ``reduced()`` twin — the *architecture decisions* are what the report
    is about, and they are driven by weight/activation statistics that the
    reduced config reproduces in kind.
    """
    cfg = configs.get(arch).reduced()
    cfg = dataclasses.replace(cfg, pim_mode=mode,
                              pim_weight_slicing="adaptive")
    params, _ = T.init_params(cfg, jax.random.key(seed))
    calib = np.asarray(jax.random.randint(
        jax.random.key(seed + 1), (calib_batch, calib_len), 0,
        cfg.vocab_size), np.int32)
    compiled = pim_compile.compile_pim_params(params, cfg, calib)
    return compiled.report(tokens=tokens)


if __name__ == "__main__":
    out = run()
    for row in out["sites"]:
        print(f"{row['site']:40s} {'-'.join(map(str, row['slicing'])):16s} "
              f"cpm={row['converts_per_mac']:.4f} "
              f"adc_share={row['adc_share']:.3f}")
    print({k: v for k, v in out.items() if k != "sites"})
