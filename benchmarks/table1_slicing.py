"""Table 1: slicing tradeoffs — bits/MAC vs ADC converts/MAC, exact."""

from __future__ import annotations


def run() -> list[dict]:
    """2b input x 2b weight, every slicing combination (paper Table 1)."""
    rows = []
    for iw, islices in [("i2", ((2,),)), ("i1", ((1, 1),))]:
        pass
    cases = [
        ("unsliced", (2,), (2,)),
        ("input-sliced", (1, 1), (2,)),
        ("weight-sliced", (2,), (1, 1)),
        ("both-sliced", (1, 1), (1, 1)),
    ]
    for name, i_s, w_s in cases:
        bits_per_mac = max(i_s) * max(w_s)
        converts_per_mac = len(i_s) * len(w_s)
        rows.append({"case": name, "bits_per_mac": bits_per_mac,
                     "converts_per_mac": converts_per_mac,
                     "cycles": len(i_s), "columns": len(w_s)})
    # paper's numbers: bits/MAC 4,2,2,1 and converts/MAC 1(x4 scale),2,2,4
    assert [r["bits_per_mac"] for r in rows] == [4, 2, 2, 1]
    assert [r["converts_per_mac"] for r in rows] == [1, 2, 2, 4]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
