"""Roofline analysis: three-term roofline per (arch x shape x mesh) from the
dry-run's compiled artifacts (results/dryrun/*.json — run
``python -m repro.launch.dryrun --all --out results/dryrun`` first).

Terms (per device, TPU v5e constants):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO bytes-accessed / 819e9
  collective = HLO collective link-bytes / 50e9
plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

``fused_report`` additionally measures the fused sliced-crossbar kernel
(``repro.kernels.fused_crossbar`` via the ``repro.kernels.ops`` registry)
against its dense-matmul ideal: the ``fused_kernel`` section of ``run()``
always runs (no dry-run artifacts needed) and reports achieved-vs-ideal
per backend plus a bit-exactness check vs the Python reference loop.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def _recompute_useful(rows: list[dict]) -> None:
    """Recompute useful-FLOPs with the embedding table excluded (older
    sweeps counted it; lookups are gathers, not MACs)."""
    try:
        from repro import configs
        from repro.configs import base as cb
    except ImportError:
        return
    shapes = {s.name: s for s in cb.ALL_SHAPES}
    for r in rows:
        try:
            cfg = configs.get(r["arch"])
            sh = shapes[r["shape"]]
        except KeyError:
            continue
        tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
        n = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
        mf = (6 if sh.kind == "train" else 2) * n * tokens / r["n_chips"]
        if r.get("hlo_flops_per_device"):
            r["model_flops_per_device"] = mf
            r["useful_flops_ratio"] = mf / r["hlo_flops_per_device"]


def load() -> list[dict]:
    path = os.path.join(RESULTS, "summary.json")
    if os.path.exists(path):
        with open(path) as f:
            rows = [r for r in json.load(f) if r.get("status") == "ok"]
        _recompute_useful(rows)
        return rows
    rows = []
    for p in glob.glob(os.path.join(RESULTS, "*.json")):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            rows.append(r)
    return rows


def table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'GiB/dev':>8s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'useful%':>8s} {'roofline%':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r["multi_pod"])):
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {mesh:8s} "
            f"{r['per_device_gib']:8.2f} {r['compute_s']:10.3e} "
            f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
            f"{r['bottleneck']:>10s} "
            f"{100 * r['useful_flops_ratio']:8.1f} "
            f"{100 * r['roofline_fraction']:9.1f}")
    return "\n".join(lines)


def fused_report(*, batch: int = 8, rows: int = 1024, cols: int = 128,
                 weight_slicing=(4, 2, 2), input_slicing=(4, 2, 2),
                 adc_bits: int = 7, reps: int = 3,
                 backends=None) -> dict:
    """Achieved-vs-ideal roofline of the fused sliced-crossbar kernel.

    'Ideal' is the pure contraction volume priced as dense matmuls: one
    (B, rows) @ (rows, cols) int32 matmul per (input-slice, weight-slice)
    pair, with no ADC clamp, shift+add, center term, or saturation
    accounting. 'Achieved' is the measured wall time of the fused kernel
    through each registry backend; ``achieved_vs_ideal = ideal / achieved``
    (1.0 means the whole exact datapath costs no more than its matmuls).
    Every backend's psum is also checked bit-exact against the Python
    reference loop (``crossbar.forward(backend='python')``).
    """
    import time as _time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import adc as adc_lib
    from repro.core import center_offset as co
    from repro.core import crossbar as xbar
    from repro.kernels import ops as kops

    if backends is None:
        backends = ("xla",)
        if jax.default_backend() == "tpu":
            backends += ("pallas-tpu",)

    rng = np.random.default_rng(0)
    enc = co.encode(rng.integers(0, 256, (rows, cols)), tuple(weight_slicing))
    planes = jnp.asarray(enc.planes)            # (n_j, n_seg, R, C)
    centers = jnp.asarray(enc.centers)
    x = jnp.asarray(rng.integers(0, 256, (batch, rows)), jnp.int32)
    adc = adc_lib.ADCConfig(bits=adc_bits)
    n_i, n_j = len(input_slicing), enc.n_slices
    rows_p = enc.n_segments * enc.rows_per_xbar

    def timed(fn):
        out = jax.block_until_ready(fn())   # compile / warm up
        t0 = _time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        return out, (_time.perf_counter() - t0) / reps

    x_pad = jnp.pad(x, ((0, 0), (0, rows_p - rows)))
    plane0 = planes.reshape(n_j, rows_p, cols)[0].astype(jnp.int32)
    dense = jax.jit(lambda a, b: jnp.einsum(
        "br,rc->bc", a, b, preferred_element_type=jnp.int32))
    _, t_dense = timed(lambda: dense(x_pad, plane0))
    ideal_s = t_dense * n_i * n_j

    oracle, _ = xbar.forward(x, enc, tuple(input_slicing), adc,
                             backend="python")
    report = {"shape": [batch, rows, cols],
              "slice_pairs": n_i * n_j,
              "ideal_s": ideal_s,
              "backends": {}}
    for be in backends:
        def fn(be=be):
            return kops.fused_crossbar_forward(
                x, planes, enc.shifts, centers,
                input_slicing=tuple(input_slicing),
                adc_lo=adc.lo, adc_hi=adc.hi,
                rows_per_xbar=enc.rows_per_xbar, backend=be)
        (psum, _), t = timed(fn)
        report["backends"][be] = {
            "time_s": t,
            "achieved_vs_ideal": round(ideal_s / t, 4),
            "bit_exact": bool((psum == oracle).all())}
    best = max(report["backends"],
               key=lambda b: report["backends"][b]["achieved_vs_ideal"])
    report["best_backend"] = best
    report["best_achieved_vs_ideal"] = \
        report["backends"][best]["achieved_vs_ideal"]
    return report


def run(*, fused_batch: int = 8, fused_rows: int = 1024,
        fused_cols: int = 128, fused_reps: int = 3,
        fused_backends=None) -> dict:
    out = {"fused_kernel": fused_report(
        batch=fused_batch, rows=fused_rows, cols=fused_cols,
        reps=fused_reps, backends=fused_backends)}
    rows = load()
    if not rows:
        out["error"] = f"no dry-run results under {RESULTS}"
        return out
    print(table(rows))
    single = [r for r in rows if not r["multi_pod"]]
    bounds = {}
    for r in single:
        bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
    worst = min(single, key=lambda r: r["roofline_fraction"])
    best = max(single, key=lambda r: r["roofline_fraction"])
    out.update({
        "cells": len(rows),
        "single_pod_cells": len(single),
        "bottleneck_histogram": bounds,
        "worst_roofline": (worst["arch"], worst["shape"],
                           round(worst["roofline_fraction"], 4)),
        "best_roofline": (best["arch"], best["shape"],
                          round(best["roofline_fraction"], 4)),
    })
    return out


if __name__ == "__main__":
    print(run())
