"""Roofline analysis: three-term roofline per (arch x shape x mesh) from the
dry-run's compiled artifacts (results/dryrun/*.json — run
``python -m repro.launch.dryrun --all --out results/dryrun`` first).

Terms (per device, TPU v5e constants):
  compute    = HLO_FLOPs / 197e12
  memory     = HLO bytes-accessed / 819e9
  collective = HLO collective link-bytes / 50e9
plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def _recompute_useful(rows: list[dict]) -> None:
    """Recompute useful-FLOPs with the embedding table excluded (older
    sweeps counted it; lookups are gathers, not MACs)."""
    try:
        from repro import configs
        from repro.configs import base as cb
    except ImportError:
        return
    shapes = {s.name: s for s in cb.ALL_SHAPES}
    for r in rows:
        try:
            cfg = configs.get(r["arch"])
            sh = shapes[r["shape"]]
        except KeyError:
            continue
        tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
        n = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
        mf = (6 if sh.kind == "train" else 2) * n * tokens / r["n_chips"]
        if r.get("hlo_flops_per_device"):
            r["model_flops_per_device"] = mf
            r["useful_flops_ratio"] = mf / r["hlo_flops_per_device"]


def load() -> list[dict]:
    path = os.path.join(RESULTS, "summary.json")
    if os.path.exists(path):
        with open(path) as f:
            rows = [r for r in json.load(f) if r.get("status") == "ok"]
        _recompute_useful(rows)
        return rows
    rows = []
    for p in glob.glob(os.path.join(RESULTS, "*.json")):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            rows.append(r)
    return rows


def table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'GiB/dev':>8s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'useful%':>8s} {'roofline%':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r["multi_pod"])):
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {mesh:8s} "
            f"{r['per_device_gib']:8.2f} {r['compute_s']:10.3e} "
            f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
            f"{r['bottleneck']:>10s} "
            f"{100 * r['useful_flops_ratio']:8.1f} "
            f"{100 * r['roofline_fraction']:9.1f}")
    return "\n".join(lines)


def run() -> dict:
    rows = load()
    if not rows:
        return {"error": f"no dry-run results under {RESULTS}"}
    print(table(rows))
    single = [r for r in rows if not r["multi_pod"]]
    bounds = {}
    for r in single:
        bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
    worst = min(single, key=lambda r: r["roofline_fraction"])
    best = max(single, key=lambda r: r["roofline_fraction"])
    return {
        "cells": len(rows),
        "single_pod_cells": len(single),
        "bottleneck_histogram": bounds,
        "worst_roofline": (worst["arch"], worst["shape"],
                           round(worst["roofline_fraction"], 4)),
        "best_roofline": (best["arch"], best["shape"],
                          round(best["roofline_fraction"], 4)),
    }


if __name__ == "__main__":
    print(run())
