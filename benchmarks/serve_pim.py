"""Fast-mode PIM serving vs the float path on the lockstep engine.

Compiles the model once with ``repro.models.pim.prepare_pim_params``
(``pim_mode='fast'``: centered int8, Eq. 1) and measures greedy decode
throughput against ``pim_mode='off'`` on the same prompts — the
whole-network serving counterpart of the per-layer Eq. 1 microbenchmark.
Also reports token agreement between the two paths: quantized logits
differ, argmax tokens should mostly survive.

  PYTHONPATH=src:. python benchmarks/serve_pim.py [--arch yi-6b]

On CPU the int8 path pays quantize/dequantize overhead without an MXU to
win it back, so the ratio here is a plumbing/consistency check; the
speedup claim is a TPU measurement (int8 MXU + halved weight traffic).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.models import pim
from repro.models import transformer as T
from repro.serve import ServeEngine


def run(arch: str = "yi-6b", requests: int = 4, prompt_len: int = 8,
        steps: int = 16, seed: int = 0) -> dict:
    if steps < 2:
        raise ValueError("steps >= 2: one greedy token from prefill plus "
                         "at least one timed decode step")
    cfg = configs.get(arch).reduced()
    params, _ = T.init_params(cfg, jax.random.key(seed))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(seed + 1), (requests, prompt_len), 0, cfg.vocab_size))
    out: dict = {"arch": cfg.name, "requests": requests, "steps": steps}
    tokens = {}
    for mode in ("off", "fast"):
        cfgm = dataclasses.replace(cfg, pim_mode=mode)
        plans, compile_s = None, 0.0
        if mode != "off":
            t0 = time.monotonic()
            plans, _ = pim.prepare_pim_params(params, cfgm, prompts)
            compile_s = time.monotonic() - t0
        eng = ServeEngine(cfgm, params, max_len=prompt_len + steps + 1,
                          plans=plans)
        # decode-only timing: drive the engine's jitted prefill/decode
        # directly so prefill cost never pollutes the decode number
        logits, state = eng._prefill(params, plans, jnp.asarray(prompts))
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
        eng._decode(params, plans, state, tok)  # warm the decode jit
        toks_out = [np.asarray(tok)[:, 0]]
        t0 = time.monotonic()
        for _ in range(steps - 1):
            logits, state = eng._decode(params, plans, state, tok)
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
            toks_out.append(np.asarray(tok)[:, 0])
        dt = time.monotonic() - t0
        tokens[mode] = np.stack(toks_out, axis=1)
        out[mode] = {
            "decode_tok_per_s": round(requests * (steps - 1) / dt, 1),
            "decode_wall_s": round(dt, 3),
            "plan_compile_s": round(compile_s, 2)}
    out["throughput_ratio_fast_over_off"] = round(
        out["fast"]["decode_tok_per_s"] / out["off"]["decode_tok_per_s"], 3)
    out["first_token_agreement"] = round(
        float((tokens["off"][:, 0] == tokens["fast"][:, 0]).mean()), 3)
    out["token_agreement"] = round(
        float((tokens["off"] == tokens["fast"]).mean()), 3)
    return out


def run_speculation(arch: str = "yi-6b", requests: int = 2,
                    prompt_len: int = 6, steps: int = 4, seed: int = 0,
                    adc_bits: int = 7,
                    kernel_backend: str | None = None) -> dict:
    """Converts/token on a real decode trace: ``pim_mode='exact'`` with
    speculation (paper §4.3) in the jitted decode step.

    The decode step is wrapped with ``layers.with_pim_stats`` (the same
    decorator the serve engines use for live telemetry): every
    exact-path projection's ``SpeculationStats`` is collected at trace
    time (scanned blocks re-emit totals as scan outputs) and the summed
    work counters ride the jitted step as auxiliary outputs — ADC
    converts, speculation failures/attempts and the no-speculation
    baseline per decoded token, the serve-time face of the paper's
    Fig. 14 convert economy. Speculation runs the fused
    ``fused_spec_crossbar`` kernel (recovery converts billed
    analytically from the failure mask), so exact+speculation decode is
    one kernel launch per projection pass, same as the static path. The
    totals also flow through ``repro.obs.record_pim_totals`` — the
    result's ``"metrics"`` block is the same Prometheus-shaped snapshot
    ``serve --metrics-out`` exports.
    """
    if steps < 2:
        raise ValueError("steps >= 2: one greedy token from prefill plus "
                         "at least one timed decode step")
    from repro.models import layers as L
    cfg = configs.get(arch).reduced()
    cfg = dataclasses.replace(cfg, pim_mode="exact", pim_speculation=True,
                              pim_adc_bits=adc_bits,
                              pim_kernel_backend=kernel_backend or "auto")
    params, _ = T.init_params(cfg, jax.random.key(seed))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(seed + 1), (requests, prompt_len), 0, cfg.vocab_size))
    plans, _ = pim.prepare_pim_params(params, cfg, prompts)

    step_j = jax.jit(L.with_pim_stats(
        lambda p, pl, st, tok: T.decode_step(p, cfg, st, tok, plans=pl)))
    prefill_j = jax.jit(lambda p, pl, toks: T.prefill(
        p, cfg, toks, max_len=prompt_len + steps + 1, plans=pl))
    logits, state = prefill_j(params, plans, jnp.asarray(prompts))
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
    step_j(params, plans, state, tok)  # warm the decode jit
    registry = obs.MetricsRegistry()
    totals = dict.fromkeys(L.PIM_STAT_KEYS, 0)
    t0 = time.monotonic()
    for _ in range(steps - 1):
        logits, state, tot = step_j(params, plans, state, tok)
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
        for k in totals:
            totals[k] += int(tot[k])
    dt = time.monotonic() - t0
    tokens = requests * (steps - 1)
    derived = obs.record_pim_totals(registry, totals, tokens, adc_bits,
                                    engine="lockstep")
    converts = totals["adc_converts"]
    no_spec = totals["no_spec_converts"]
    return {
        "arch": cfg.name, "requests": requests, "steps": steps,
        "adc_bits": adc_bits,
        "decode_tok_per_s": round(tokens / dt, 1),
        "adc_converts_per_token": round(converts / tokens, 1),
        "no_spec_converts_per_token": round(no_spec / tokens, 1),
        "convert_ratio_vs_no_spec": round(converts / max(no_spec, 1), 4),
        "spec_failure_rate": round(
            totals["spec_failures"] / max(totals["spec_attempts"], 1), 5),
        "recovery_saturations": totals["recovery_saturations"],
        "pj_per_token": round(derived["pj_per_token"], 2),
        "adc_pj_per_token": round(derived["adc_pj_per_token"], 2),
        "metrics": obs.snapshot(registry),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--speculation", action="store_true",
                    help="run the exact-mode speculation converts/token "
                         "report instead of the fast-vs-off throughput "
                         "comparison")
    args = ap.parse_args()
    if args.speculation:
        out = run_speculation(args.arch, args.requests, args.prompt_len,
                              args.steps)
        print(f"{out['arch']}: {args.requests} requests x {args.steps} steps "
              f"(exact + speculation, {out['adc_bits']}b ADC)")
        print(f"  {out['decode_tok_per_s']:8.1f} tok/s decode")
        print(f"  {out['adc_converts_per_token']:.1f} converts/token vs "
              f"{out['no_spec_converts_per_token']:.1f} no-spec "
              f"({out['convert_ratio_vs_no_spec']}x), failure rate "
              f"{out['spec_failure_rate']}")
        print(f"  {out['pj_per_token']:.1f} pJ/token estimated "
              f"(ADC {out['adc_pj_per_token']:.1f})")
        return
    out = run(args.arch, args.requests, args.prompt_len, args.steps)
    print(f"{out['arch']}: {args.requests} requests x {args.steps} steps")
    for mode in ("off", "fast"):
        r = out[mode]
        print(f"  {mode:4s} {r['decode_tok_per_s']:8.1f} tok/s "
              f"(compile {r['plan_compile_s']:.2f}s)")
    print(f"  ratio {out['throughput_ratio_fast_over_off']}x, "
          f"token agreement {out['token_agreement']}")


if __name__ == "__main__":
    main()
