"""Fast-mode PIM serving vs the float path on the lockstep engine.

Compiles the model once with ``repro.models.pim.prepare_pim_params``
(``pim_mode='fast'``: centered int8, Eq. 1) and measures greedy decode
throughput against ``pim_mode='off'`` on the same prompts — the
whole-network serving counterpart of the per-layer Eq. 1 microbenchmark.
Also reports token agreement between the two paths: quantized logits
differ, argmax tokens should mostly survive.

  PYTHONPATH=src:. python benchmarks/serve_pim.py [--arch yi-6b]

On CPU the int8 path pays quantize/dequantize overhead without an MXU to
win it back, so the ratio here is a plumbing/consistency check; the
speedup claim is a TPU measurement (int8 MXU + halved weight traffic).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import pim
from repro.models import transformer as T
from repro.serve import ServeEngine


def run(arch: str = "yi-6b", requests: int = 4, prompt_len: int = 8,
        steps: int = 16, seed: int = 0) -> dict:
    if steps < 2:
        raise ValueError("steps >= 2: one greedy token from prefill plus "
                         "at least one timed decode step")
    cfg = configs.get(arch).reduced()
    params, _ = T.init_params(cfg, jax.random.key(seed))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(seed + 1), (requests, prompt_len), 0, cfg.vocab_size))
    out: dict = {"arch": cfg.name, "requests": requests, "steps": steps}
    tokens = {}
    for mode in ("off", "fast"):
        cfgm = dataclasses.replace(cfg, pim_mode=mode)
        plans, compile_s = None, 0.0
        if mode != "off":
            t0 = time.monotonic()
            plans, _ = pim.prepare_pim_params(params, cfgm, prompts)
            compile_s = time.monotonic() - t0
        eng = ServeEngine(cfgm, params, max_len=prompt_len + steps + 1,
                          plans=plans)
        # decode-only timing: drive the engine's jitted prefill/decode
        # directly so prefill cost never pollutes the decode number
        logits, state = eng._prefill(params, plans, jnp.asarray(prompts))
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
        eng._decode(params, plans, state, tok)  # warm the decode jit
        toks_out = [np.asarray(tok)[:, 0]]
        t0 = time.monotonic()
        for _ in range(steps - 1):
            logits, state = eng._decode(params, plans, state, tok)
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None]
            toks_out.append(np.asarray(tok)[:, 0])
        dt = time.monotonic() - t0
        tokens[mode] = np.stack(toks_out, axis=1)
        out[mode] = {
            "decode_tok_per_s": round(requests * (steps - 1) / dt, 1),
            "decode_wall_s": round(dt, 3),
            "plan_compile_s": round(compile_s, 2)}
    out["throughput_ratio_fast_over_off"] = round(
        out["fast"]["decode_tok_per_s"] / out["off"]["decode_tok_per_s"], 3)
    out["first_token_agreement"] = round(
        float((tokens["off"][:, 0] == tokens["fast"][:, 0]).mean()), 3)
    out["token_agreement"] = round(
        float((tokens["off"] == tokens["fast"]).mean()), 3)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()
    out = run(args.arch, args.requests, args.prompt_len, args.steps)
    print(f"{out['arch']}: {args.requests} requests x {args.steps} steps")
    for mode in ("off", "fast"):
        r = out[mode]
        print(f"  {mode:4s} {r['decode_tok_per_s']:8.1f} tok/s "
              f"(compile {r['plan_compile_s']:.2f}s)")
    print(f"  ratio {out['throughput_ratio_fast_over_off']}x, "
          f"token agreement {out['token_agreement']}")


if __name__ == "__main__":
    main()
