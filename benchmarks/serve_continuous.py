"""Lockstep vs continuous batching on a mixed-length request trace.

The lockstep engine must decode every batch until its slowest request
finishes, so decode-step utilization (non-padding tokens per step /
slots) collapses when output lengths are ragged. The continuous engine
retires each request the step it finishes and admits the next one from
the queue, so utilization stays near 1 while per-request greedy outputs
remain bit-identical.

  PYTHONPATH=src:. python benchmarks/serve_continuous.py [--arch yi-6b]

Prints utilization for both engines and the ratio; exits non-zero if the
ratio falls under the 1.5x acceptance floor or any output diverges.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs, obs
from repro.models import transformer as T
from repro.serve import ContinuousServeEngine, Request, ServeEngine


@dataclasses.dataclass
class TraceGroup:
    """Requests sharing one prompt length (the lockstep engine's admission
    constraint) but ragged output lengths."""
    prompts: np.ndarray           # (B, plen)
    steps: list


def build_trace(n_groups: int, n_slots: int, vocab: int,
                seed: int = 0) -> list[TraceGroup]:
    """Heavy-tailed decode lengths: most requests are short, one straggler
    per group runs ~8x longer (the chat/completions mix that motivates
    continuous batching)."""
    rng = np.random.default_rng(seed)
    groups = []
    for g in range(n_groups):
        plen = int(rng.integers(4, 12))
        steps = sorted(int(rng.integers(3, 9)) for _ in range(n_slots - 1))
        steps.append(int(rng.integers(32, 41)))   # straggler
        rng.shuffle(steps)
        groups.append(TraceGroup(
            prompts=rng.integers(0, vocab, (n_slots, plen)).astype(np.int32),
            steps=steps))
    return groups


def run(arch: str = "yi-6b", n_groups: int = 3, n_slots: int = 4,
        prefill_chunk: int = 8, seed: int = 0) -> dict:
    cfg = configs.get(arch).reduced()
    params, _ = T.init_params(cfg, jax.random.key(0))
    trace = build_trace(n_groups, n_slots, cfg.vocab_size, seed)
    max_len = max(int(g.prompts.shape[1]) + max(g.steps) for g in trace) + 1

    # ---- lockstep: each group decodes to its slowest request
    lock = ServeEngine(cfg, params, max_len=max_len)
    lock_outputs: dict[int, np.ndarray] = {}
    lock_steps = lock_tokens = 0
    t0 = time.monotonic()
    uid = 0
    for g in trace:
        res = lock.generate(g.prompts, steps=max(g.steps))
        for b, steps in enumerate(g.steps):
            lock_outputs[uid] = res.tokens[b, :steps]
            uid += 1
        # decode-only accounting, same definition as EngineStats: the
        # first token of each request comes out of prefill, not a
        # decode_step, so it appears in neither numerator nor denominator
        lock_steps += max(g.steps) - 1
        lock_tokens += sum(s - 1 for s in g.steps)
    lock_dt = time.monotonic() - t0
    lock_util = lock_tokens / (lock_steps * n_slots)

    # ---- continuous: one queue over the same requests, arrival order
    reqs, uid = [], 0
    for g in trace:
        for b, steps in enumerate(g.steps):
            reqs.append(Request(uid=uid, prompt=g.prompts[b],
                                max_new_tokens=steps))
            uid += 1
    tel = obs.ServeTelemetry(engine="continuous")
    cont = ContinuousServeEngine(cfg, params, n_slots=n_slots,
                                 max_len=max_len,
                                 prefill_chunk=prefill_chunk,
                                 telemetry=tel)
    t0 = time.monotonic()
    outs = cont.run(reqs)
    cont_dt = time.monotonic() - t0
    cont_util = cont.stats.decode_utilization / n_slots
    tel.record_stats(cont.stats)

    mismatches = [o.uid for o in outs
                  if not np.array_equal(o.tokens, lock_outputs[o.uid])]
    return {
        "arch": cfg.name,
        "requests": len(reqs),
        "lockstep_util": lock_util,
        "continuous_util": cont_util,
        "util_ratio": cont_util / lock_util,
        "lockstep_decode_steps": lock_steps,
        "continuous_decode_steps": cont.stats.decode_steps,
        "prefill_chunks": cont.stats.prefill_chunks,
        "lockstep_s": lock_dt,
        "continuous_s": cont_dt,
        "bit_identical": not mismatches,
        "mismatched_uids": mismatches,
        "stats": cont.stats.snapshot(),
        "metrics": obs.snapshot(tel.registry),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    args = ap.parse_args()
    out = run(args.arch, args.groups, args.slots, args.prefill_chunk)
    print(f"{out['arch']}: {out['requests']} requests over {args.slots} "
          f"slots")
    print(f"  lockstep   util {out['lockstep_util']:.2f} "
          f"({out['lockstep_decode_steps']} decode steps, "
          f"{out['lockstep_s']:.1f}s)")
    print(f"  continuous util {out['continuous_util']:.2f} "
          f"({out['continuous_decode_steps']} decode steps, "
          f"{out['prefill_chunks']} prefill chunks, "
          f"{out['continuous_s']:.1f}s)")
    print(f"  ratio {out['util_ratio']:.2f}x, bit-identical outputs: "
          f"{out['bit_identical']}")
    if not out["bit_identical"]:
        raise SystemExit(f"outputs diverged: uids {out['mismatched_uids']}")
    if out["util_ratio"] < 1.5:
        raise SystemExit(
            f"utilization ratio {out['util_ratio']:.2f}x under the 1.5x "
            f"floor")


if __name__ == "__main__":
    main()
