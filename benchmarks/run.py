"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the headline metric of
that artifact).
"""

from __future__ import annotations

import time


def _row(name, fn, derive):
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(out)}")
    return out


def main() -> None:
    from benchmarks import (fig3_column_sums, fig12_efficiency, fig13_retrain,
                            fig14_ablation, fig15_noise, lm_on_pim, roofline,
                            serve_continuous, table1_slicing, table2_titanium,
                            table4_accuracy)
    print("name,us_per_call,derived")
    _row("table1_slicing", table1_slicing.run,
         lambda o: f"bits/MAC x converts/MAC tradeoff over {len(o)} slicings")
    _row("table2_titanium", table2_titanium.run,
         lambda o: "law_matches=" + str(all(v["law_matches"]
                                            for v in o.values())))
    _row("fig3_column_sums", fig3_column_sums.run,
         lambda o: "le7b: " + " -> ".join(
             f"{o[k]['le7b']:.2f}" for k in
             ["baseline_unsigned_4b", "center_offset", "adaptive_slicing",
              "recovery_cycles"]))
    _row("fig12_efficiency", fig12_efficiency.run,
         lambda o: f"geomean eff {o['geomean']['efficiency_x']:.2f}x "
                   f"thpt {o['geomean']['throughput_x']:.2f}x "
                   f"(paper 3.9/2.0)")
    _row("fig13_retrain", fig13_retrain.run,
         lambda o: f"RAELLA {o['raella_vs_isaac']['efficiency_x']:.2f}x vs "
                   f"FORMS {o['forms8_vs_isaac']['efficiency_x']:.2f}x / "
                   f"TIMELY {o['timely_vs_isaac']['efficiency_x']:.2f}x "
                   f"(no retraining)")
    _row("fig14_ablation", fig14_ablation.run,
         lambda o: "converts/MAC " + " -> ".join(
             f"{v['ideal_converts_per_mac']:.3f}" for v in o.values())
         + " (paper 0.25->0.063->0.047->0.018)")
    _row("table4_accuracy", table4_accuracy.run,
         lambda o: f"sec4.2.1 err C+O {o['center']['sec4.2.1_error']} vs "
                   f"Z+O {o['zero']['sec4.2.1_error']}; acc drop "
                   f"{o['center']['accuracy_drop_pts']} vs "
                   f"{o['zero']['accuracy_drop_pts']} pts")
    _row("fig15_noise", fig15_noise.run,
         lambda o: "acc@12% noise: " + " ".join(
             f"{k}={v:.2f}" for k, v in o["noise_0.12"].items()
             if isinstance(v, float)))
    _row("lm_on_pim", lm_on_pim.run,
         lambda o: f"assigned-LM zoo on RAELLA silicon: "
                   f"{o['geomean_efficiency_x']}x geomean vs 8b-ISAAC")
    _row("roofline", roofline.run,
         lambda o: f"{o.get('cells', 0)} cells, "
                   f"bottlenecks {o.get('bottleneck_histogram')}")
    _row("serve_continuous", serve_continuous.run,
         lambda o: f"decode util {o['lockstep_util']:.2f} -> "
                   f"{o['continuous_util']:.2f} "
                   f"({o['util_ratio']:.2f}x, floor 1.5x), bit-identical="
                   f"{o['bit_identical']}")


if __name__ == "__main__":
    main()
