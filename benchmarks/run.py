"""Benchmark runner: one registry entry per paper table/figure + system
benchmark.

Prints ``name,us_per_call,derived`` CSV (derived = the headline metric of
that artifact). ``REGISTRY`` is the canonical list of runnable entries —
``tests/test_benchmarks_smoke.py`` executes every entry at its
``smoke_kwargs`` toy sizes and asserts JSON-serializable output.

Result recording (the ONE schema every benchmark persists through)::

  python benchmarks/run.py --record serve_paged    # -> BENCH_serve_paged.json
  python benchmarks/run.py --record serve_paged --full   # full-size kwargs
  python benchmarks/run.py --check serve_paged     # re-run + compare

``--record`` runs an entry (at its smoke kwargs by default) and writes
``BENCH_<entry>.json``: ``{schema, entry, kwargs, git_sha, derived,
result}``. ``--check`` re-runs with the *stored* kwargs and compares the
result trees leaf-by-leaf — wall-clock keys (``*_s``, ``*_us``,
``*seconds*``, ``*tok_per_s*``) are pruned since timings are
nondeterministic; remaining floats compare at ``rtol`` (default 0.1),
everything else exactly. CI's perf-smoke leg runs ``--check serve_paged``
so schema or determinism drift fails fast.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib
import json
import os
import re
import subprocess
import sys
import time
from typing import Callable

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA_VERSION = 1
# nondeterministic leaves: wall times and throughputs (latency histogram
# metric names also carry the "seconds" suffix — Prometheus convention)
_TIMING_KEY = re.compile(r"(_s$|_us$|seconds|tok_per_s|_time$)")


@dataclasses.dataclass(frozen=True)
class Entry:
    """One benchmark: a ``benchmarks.<module>.<attr>`` plus its headline
    formatter and the kwargs that shrink it to smoke-test size."""
    module: str
    derive: Callable[[object], str]
    smoke_kwargs: dict = dataclasses.field(default_factory=dict)
    attr: str = "run"   # entry point inside the module (e.g. the
                        # device-corner sweeps' run_device_corners)

    def run(self, **kwargs):
        return getattr(importlib.import_module(f"benchmarks.{self.module}"),
                       self.attr)(**kwargs)


REGISTRY: dict[str, Entry] = {
    "table1_slicing": Entry(
        "table1_slicing",
        lambda o: f"bits/MAC x converts/MAC tradeoff over {len(o)} slicings"),
    "table2_titanium": Entry(
        "table2_titanium",
        lambda o: "law_matches=" + str(all(v["law_matches"]
                                          for v in o.values()))),
    "fig3_column_sums": Entry(
        "fig3_column_sums",
        lambda o: "le7b: " + " -> ".join(
            f"{o[k]['le7b']:.2f}" for k in
            ["baseline_unsigned_4b", "center_offset", "adaptive_slicing",
             "recovery_cycles"])),
    "fig12_efficiency": Entry(
        "fig12_efficiency",
        lambda o: f"geomean eff {o['geomean']['efficiency_x']:.2f}x "
                  f"thpt {o['geomean']['throughput_x']:.2f}x "
                  f"(paper 3.9/2.0)"),
    "fig13_retrain": Entry(
        "fig13_retrain",
        lambda o: f"RAELLA {o['raella_vs_isaac']['efficiency_x']:.2f}x vs "
                  f"FORMS {o['forms8_vs_isaac']['efficiency_x']:.2f}x / "
                  f"TIMELY {o['timely_vs_isaac']['efficiency_x']:.2f}x "
                  f"(no retraining)"),
    "fig14_ablation": Entry(
        "fig14_ablation",
        lambda o: "converts/MAC " + " -> ".join(
            f"{v['ideal_converts_per_mac']:.3f}" for v in o.values())
        + " (paper 0.25->0.063->0.047->0.018)"),
    "table4_accuracy": Entry(
        "table4_accuracy",
        lambda o: f"sec4.2.1 err C+O {o['center']['sec4.2.1_error']} vs "
                  f"Z+O {o['zero']['sec4.2.1_error']}; acc drop "
                  f"{o['center']['accuracy_drop_pts']} vs "
                  f"{o['zero']['accuracy_drop_pts']} pts",
        smoke_kwargs=dict(train_steps=300, eval_n=256)),
    "fig15_noise": Entry(
        "fig15_noise",
        lambda o: "acc@12% noise: " + " ".join(
            f"{k}={v:.2f}" for k, v in o["noise_0.12"].items()
            if isinstance(v, float)),
        smoke_kwargs=dict(noise_levels=(0.12,), eval_n=512,
                          train_steps=300)),
    "fig15_corners": Entry(
        "fig15_noise",
        lambda o: "acc by die corner (one compiled plan): " + " ".join(
            f"{k[len('corner_'):]}={v['accuracy']:.2f}"
            for k, v in o.items() if k.startswith("corner_")),
        smoke_kwargs=dict(corners=("nominal", "3sigma"), eval_n=256,
                          train_steps=300),
        attr="run_device_corners"),
    "table4_corners": Entry(
        "table4_accuracy",
        lambda o: f"3sigma die drop C+O "
                  f"{o['center']['3sigma']['drop_pts']} vs Z+O "
                  f"{o['zero']['3sigma']['drop_pts']} pts (no retraining)",
        smoke_kwargs=dict(corners=("nominal", "3sigma"), eval_n=256,
                          train_steps=300),
        attr="run_device_corners"),
    "lm_on_pim": Entry(
        "lm_on_pim",
        lambda o: f"assigned-LM zoo on RAELLA silicon: "
                  f"{o['geomean_efficiency_x']}x geomean vs 8b-ISAAC",
        smoke_kwargs=dict(tokens=128)),
    "roofline": Entry(
        "roofline",
        lambda o: f"fused {o['fused_kernel']['best_backend']} "
                  f"achieved-vs-ideal "
                  f"{o['fused_kernel']['best_achieved_vs_ideal']} "
                  f"(bit_exact="
                  + str(all(b["bit_exact"]
                            for b in o["fused_kernel"]["backends"].values()))
                  + f"); {o.get('cells', 0)} dry-run cells, "
                  f"bottlenecks {o.get('bottleneck_histogram')}",
        smoke_kwargs=dict(fused_batch=2, fused_rows=96, fused_cols=8,
                          fused_reps=1,
                          fused_backends=("xla", "interpret"))),
    "serve_continuous": Entry(
        "serve_continuous",
        lambda o: f"decode util {o['lockstep_util']:.2f} -> "
                  f"{o['continuous_util']:.2f} "
                  f"({o['util_ratio']:.2f}x, floor 1.5x), bit-identical="
                  f"{o['bit_identical']}",
        smoke_kwargs=dict(n_groups=1)),
    "serve_paged": Entry(
        "serve_paged",
        lambda o: f"admitted mean {o['contiguous_mean_admitted']} -> "
                  f"{o['paged_mean_admitted']} "
                  f"({o['admission_ratio']}x on {o['budget_tokens']} KV "
                  f"tokens), peak {o['contiguous_peak_admitted']} -> "
                  f"{o['paged_peak_admitted']}, "
                  f"prefix hits {o['paged_prefix_block_hits']}, "
                  f"bit-identical={o['bit_identical']}",
        smoke_kwargs=dict(n_requests=4, disaggregated=False)),
    "compile_report": Entry(
        "compile_report",
        lambda o: f"{o['n_sites']} sites, slices {o['slice_histogram']}, "
                  f"converts/MAC {o['converts_per_mac']}, "
                  f"adc share {o['adc_energy_share']}",
        smoke_kwargs=dict(arch="yi-6b", tokens=128, calib_len=6)),
    "serve_pim": Entry(
        "serve_pim",
        lambda o: f"pim fast decode "
                  f"{o['fast']['decode_tok_per_s']:.1f} tok/s vs off "
                  f"{o['off']['decode_tok_per_s']:.1f} "
                  f"({o['throughput_ratio_fast_over_off']}x), token "
                  f"agreement {o['token_agreement']}",
        smoke_kwargs=dict(requests=2, steps=4)),
    "serve_pim_spec": Entry(
        "serve_pim",
        lambda o: f"exact+speculation decode "
                  f"{o['adc_converts_per_token']:.1f} converts/token vs "
                  f"{o['no_spec_converts_per_token']:.1f} no-spec "
                  f"({o['convert_ratio_vs_no_spec']}x), failure rate "
                  f"{o['spec_failure_rate']}, "
                  f"{o['decode_tok_per_s']:.1f} tok/s",
        smoke_kwargs=dict(requests=2, steps=3, prompt_len=4),
        attr="run_speculation"),
}


def _row(name, fn, derive):
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(out)}")
    return out


# ------------------------------------------------------- record / check
def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_ROOT,
            capture_output=True, text=True, check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def record_path(name: str) -> str:
    return os.path.join(_ROOT, f"BENCH_{name}.json")


def write_record(name: str, result, kwargs: dict, *,
                 derived: str | None = None, path: str | None = None) -> str:
    """Persist one benchmark result under the shared record schema.

    Benchmarks that write a JSON artifact route through here (rather
    than each growing its own ad-hoc writer) so ``--check`` and CI read
    one shape. ``default=float`` normalizes numpy scalars.
    """
    doc = {"schema": SCHEMA_VERSION, "entry": name, "kwargs": kwargs,
           "git_sha": _git_sha(), "derived": derived, "result": result}
    path = path if path is not None else record_path(name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=float)
        f.write("\n")
    return path


def record(name: str, *, full: bool = False) -> str:
    entry = REGISTRY[name]
    kwargs = {} if full else dict(entry.smoke_kwargs)
    out = entry.run(**kwargs)
    return write_record(name, out, kwargs, derived=entry.derive(out))


def _compare(base, new, path: str, problems: list[str],
             rtol: float) -> None:
    """Leaf-by-leaf tolerance compare; appends a line per mismatch."""
    if isinstance(base, dict):
        if not isinstance(new, dict):
            problems.append(f"{path}: dict -> {type(new).__name__}")
            return
        for k, v in base.items():
            if _TIMING_KEY.search(str(k)):
                continue                    # wall clocks: pruned subtree
            if k not in new:
                problems.append(f"{path}.{k}: missing")
            else:
                _compare(v, new[k], f"{path}.{k}", problems, rtol)
        return
    if isinstance(base, (list, tuple)):
        if not isinstance(new, (list, tuple)) or len(new) != len(base):
            problems.append(f"{path}: list shape {base!r} vs {new!r}")
            return
        for i, (b, n) in enumerate(zip(base, new)):
            _compare(b, n, f"{path}[{i}]", problems, rtol)
        return
    if isinstance(base, bool) or isinstance(new, bool):
        if base is not new:
            problems.append(f"{path}: {base!r} vs {new!r}")
        return
    if isinstance(base, (int, float)) and isinstance(new, (int, float)):
        if isinstance(base, int) and isinstance(new, int):
            if base != new:
                problems.append(f"{path}: {base} vs {new}")
        elif abs(new - base) > rtol * max(abs(base), 1e-12):
            problems.append(f"{path}: {base!r} vs {new!r} (rtol {rtol})")
        return
    if base != new:
        problems.append(f"{path}: {base!r} vs {new!r}")


def check(name: str, *, rtol: float = 0.1) -> list[str]:
    """Re-run ``name`` with its recorded kwargs; return mismatch lines
    (empty = the recorded baseline still reproduces)."""
    path = record_path(name)
    if not os.path.exists(path):
        return [f"{path}: no recorded baseline — run --record {name}"]
    with open(path) as f:
        doc = json.load(f)
    out = REGISTRY[name].run(**doc.get("kwargs", {}))
    out = json.loads(json.dumps(out, default=float))   # normalize as stored
    problems: list[str] = []
    _compare(doc["result"], out, "result", problems, rtol)
    return problems


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--record", metavar="ENTRY", choices=sorted(REGISTRY),
                    help="run ENTRY and write BENCH_<ENTRY>.json")
    ap.add_argument("--check", metavar="ENTRY", choices=sorted(REGISTRY),
                    help="re-run ENTRY with its recorded kwargs and compare "
                         "against BENCH_<ENTRY>.json (exit 1 on drift)")
    ap.add_argument("--full", action="store_true",
                    help="--record at the entry's full-size default kwargs "
                         "instead of its smoke kwargs")
    ap.add_argument("--rtol", type=float, default=0.1,
                    help="--check float tolerance (relative)")
    args = ap.parse_args(argv)
    if args.record:
        print(f"recorded {args.record} -> {record(args.record, full=args.full)}")
        return
    if args.check:
        problems = check(args.check, rtol=args.rtol)
        if problems:
            print(f"{args.check}: {len(problems)} mismatches vs "
                  f"{record_path(args.check)}")
            for p in problems:
                print(f"  {p}")
            sys.exit(1)
        print(f"{args.check}: OK vs {record_path(args.check)}")
        return
    print("name,us_per_call,derived")
    for name, entry in REGISTRY.items():
        _row(name, entry.run, entry.derive)


if __name__ == "__main__":
    main()
