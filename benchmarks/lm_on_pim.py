"""Beyond-paper: the 10 assigned LM architectures served on RAELLA silicon.

Maps every weight-static matmul of each assigned ArchConfig onto the
Titanium-Law model and reports RAELLA vs 8b-ISAAC serving efficiency /
throughput — extending the paper's BERT-feedforward experiment (§6.2) to
the modern LM zoo (GQA, MoE, Mamba, RWKV6). Signed activations use the
paper's two-cycle input processing throughout.
"""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.core import energy as en
from repro.core.lm_workloads import from_arch_config


def run(tokens: int = 1024) -> dict:
    out = {}
    ratios = []
    for arch in configs.ASSIGNED:
        cfg = configs.get(arch)
        layers = from_arch_config(cfg, tokens=tokens)
        ri = en.analyze_dnn(en.ISAAC_8B, layers, replicate=False)
        rr = en.analyze_dnn(en.RAELLA, layers, replicate=False)
        eff = ri.energy / rr.energy
        ratios.append(eff)
        out[arch] = {
            "pim_layers": len(layers),
            "macs_per_token": ri.macs // tokens,
            "raella_converts_per_mac": round(rr.converts_per_mac, 4),
            "efficiency_vs_isaac_x": round(eff, 2),
            "raella_uJ_per_token": round(rr.energy / tokens / 1e6, 2),
        }
    out["geomean_efficiency_x"] = round(
        float(np.exp(np.mean(np.log(ratios)))), 2)
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
