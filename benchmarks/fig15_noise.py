"""Fig. 15: accuracy vs analog noise for the four ablation setups.

Setups follow §7: ISAAC-like (128-row unsigned, 8b ADC), +Center+Offset
(512-row 2T2R, 7b ADC), +Adaptive Weight Slicing (noise-aware slicing
choice), full RAELLA (speculation+recovery). Noise: N(mu, (E*sqrt(N+ +
N-))^2) added to column sums. Paper: ISAAC collapses by ~4% noise;
RAELLA's strategies hold accuracy to much higher noise."""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import (build_pim_plans, mlp_accuracy, pim_layer_fn,
                               plans_layer_fn, trained_mlp)
from repro.core import adaptive, backends
from repro.core import adc as adc_lib

NOISE_LEVELS = (0.0, 0.04, 0.08, 0.12)


def run(noise_levels: tuple = NOISE_LEVELS, eval_n: int = 2048,
        train_steps: int = 1500) -> dict:
    mlp, ds = trained_mlp(steps=train_steps)
    out = {"float_reference": mlp_accuracy(mlp, ds, n=eval_n)}
    isaac_adc = adc_lib.ADCConfig(bits=8, signed=False)

    for level in noise_levels:
        row = {}
        # ISAAC: unsigned arithmetic, 128-row crossbars, 8b unsigned ADC
        layer = pim_layer_fn(mlp, ds, encode_mode="unsigned",
                             weight_slicing=(2, 2, 2, 2), adc=isaac_adc,
                             speculation=False, noise_level=level,
                             rows_per_xbar=128)
        row["isaac"] = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        # + Center+Offset: 512-row 2T2R, 7b signed ADC
        layer = pim_layer_fn(mlp, ds, encode_mode="center",
                             weight_slicing=(2, 2, 2, 2),
                             speculation=False, noise_level=level)
        row["center_offset"] = mlp_accuracy(mlp, ds, n=eval_n,
                                           layer_fn=layer)
        # + Adaptive Weight Slicing (noise-aware choice on layer 1)
        x_cal, _ = ds.batch(77, 10)
        choice = adaptive.find_best_slicing(
            mlp.w1, x_cal, noise_level=level, key=jax.random.key(1))
        layer = pim_layer_fn(mlp, ds, encode_mode="center",
                             weight_slicing=choice.slicing,
                             speculation=False, noise_level=level)
        row["adaptive"] = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        row["adaptive_n_slices"] = choice.n_slices
        # full RAELLA (speculation + recovery)
        layer = pim_layer_fn(mlp, ds, encode_mode="center",
                             weight_slicing=choice.slicing,
                             speculation=True, noise_level=level)
        row["raella"] = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        out[f"noise_{level:.2f}"] = row
    return out


def run_device_corners(corners: tuple = ("nominal", "1sigma", "3sigma"),
                       eval_n: int = 2048, train_steps: int = 1500,
                       die_seeds: tuple = (0,)) -> dict:
    """Accuracy vs ReRAM device corner on ONE compiled plan.

    The plan — Algorithm-1 slicing choice + Center+Offset encode — is
    compiled once at nominal; each corner then only swaps the analog
    array model (``repro.core.backends.NonidealSim``: conductance program
    noise, retention drift, stuck-at fault maps, IR drop). That is the
    write-once/read-many question a fab cares about: does the *unmodified*
    programmed die image survive a 1-sigma / 3-sigma die? The ``nominal``
    corner is the all-zero magnitudes die, bit-exact with the ideal sim
    (the zero-corner contract), so its row doubles as the reference."""
    mlp, ds = trained_mlp(steps=train_steps)
    acc_f = mlp_accuracy(mlp, ds, n=eval_n)
    x_cal, _ = ds.batch(77, 10)
    choice = adaptive.find_best_slicing(mlp.w1, x_cal,
                                        key=jax.random.key(1))
    plans = build_pim_plans(mlp, ds, encode_mode="center",
                            weight_slicing=choice.slicing,
                            speculation=False)
    out = {"float_reference": acc_f,
           "slicing": list(choice.slicing)}
    for name in corners:
        accs = []
        for seed in die_seeds:
            dev = backends.make("nonideal", name, seed=seed)
            layer = plans_layer_fn(plans, device=dev)
            accs.append(mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer))
        acc = sum(accs) / len(accs)
        out[f"corner_{name}"] = {
            "accuracy": acc,
            "drop_pts": round(100 * (acc_f - acc), 2),
            "dies": len(accs),
        }
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--device-corner", default=None,
                    choices=tuple(backends.CORNERS),
                    help="sweep device corners (nominal..the named corner) "
                         "on one compiled plan instead of the noise figure")
    ap.add_argument("--eval-n", type=int, default=2048)
    ap.add_argument("--train-steps", type=int, default=1500)
    args = ap.parse_args()
    if args.device_corner is not None:
        names = tuple(backends.CORNERS)
        sweep = names[:names.index(args.device_corner) + 1]
        res = run_device_corners(corners=sweep, eval_n=args.eval_n,
                                 train_steps=args.train_steps)
    else:
        res = run(eval_n=args.eval_n, train_steps=args.train_steps)
    for k, v in res.items():
        print(k, v)
