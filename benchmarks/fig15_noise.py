"""Fig. 15: accuracy vs analog noise for the four ablation setups.

Setups follow §7: ISAAC-like (128-row unsigned, 8b ADC), +Center+Offset
(512-row 2T2R, 7b ADC), +Adaptive Weight Slicing (noise-aware slicing
choice), full RAELLA (speculation+recovery). Noise: N(mu, (E*sqrt(N+ +
N-))^2) added to column sums. Paper: ISAAC collapses by ~4% noise;
RAELLA's strategies hold accuracy to much higher noise."""

from __future__ import annotations

import jax

from benchmarks.common import mlp_accuracy, pim_layer_fn, trained_mlp
from repro.core import adaptive
from repro.core import adc as adc_lib

NOISE_LEVELS = (0.0, 0.04, 0.08, 0.12)


def run(noise_levels: tuple = NOISE_LEVELS, eval_n: int = 2048,
        train_steps: int = 1500) -> dict:
    mlp, ds = trained_mlp(steps=train_steps)
    out = {"float_reference": mlp_accuracy(mlp, ds, n=eval_n)}
    isaac_adc = adc_lib.ADCConfig(bits=8, signed=False)

    for level in noise_levels:
        row = {}
        # ISAAC: unsigned arithmetic, 128-row crossbars, 8b unsigned ADC
        layer = pim_layer_fn(mlp, ds, encode_mode="unsigned",
                             weight_slicing=(2, 2, 2, 2), adc=isaac_adc,
                             speculation=False, noise_level=level,
                             rows_per_xbar=128)
        row["isaac"] = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        # + Center+Offset: 512-row 2T2R, 7b signed ADC
        layer = pim_layer_fn(mlp, ds, encode_mode="center",
                             weight_slicing=(2, 2, 2, 2),
                             speculation=False, noise_level=level)
        row["center_offset"] = mlp_accuracy(mlp, ds, n=eval_n,
                                           layer_fn=layer)
        # + Adaptive Weight Slicing (noise-aware choice on layer 1)
        x_cal, _ = ds.batch(77, 10)
        choice = adaptive.find_best_slicing(
            mlp.w1, x_cal, noise_level=level, key=jax.random.key(1))
        layer = pim_layer_fn(mlp, ds, encode_mode="center",
                             weight_slicing=choice.slicing,
                             speculation=False, noise_level=level)
        row["adaptive"] = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        row["adaptive_n_slices"] = choice.n_slices
        # full RAELLA (speculation + recovery)
        layer = pim_layer_fn(mlp, ds, encode_mode="center",
                             weight_slicing=choice.slicing,
                             speculation=True, noise_level=level)
        row["raella"] = mlp_accuracy(mlp, ds, n=eval_n, layer_fn=layer)
        out[f"noise_{level:.2f}"] = row
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
