"""Table 2: the Titanium Law — term-by-term ADC energy decomposition."""

from __future__ import annotations

from repro.core import energy as en
from repro.core import workloads as wl


def run() -> dict:
    out = {}
    layers = wl.resnet18()
    for arch in [en.ISAAC_8B, en.RAELLA]:
        rep = en.analyze_dnn(arch, layers, replicate=False)
        macs = rep.macs
        cpm = rep.converts_per_mac
        epc = en.adc_energy_per_convert(arch.adc_bits)
        util = sum(l.mapping.utilization * l.layer.macs
                   for l in rep.layers) / macs
        # the law: E = E/convert x converts/MAC x MACs x 1/util
        # (our converts already include the utilization inflation, so the
        # identity check multiplies the *ideal* cpm by 1/util)
        e_adc = rep.energy_breakdown["e_adc"]
        law = en.titanium_law(epc, cpm, macs, 1.0)
        out[arch.name] = {
            "energy_per_convert_pJ": epc,
            "converts_per_mac": cpm,
            "macs": macs,
            "mean_row_utilization": util,
            "adc_energy_uJ": e_adc / 1e6,
            "titanium_law_uJ": law / 1e6,
            "law_matches": abs(law - e_adc) / e_adc < 1e-6,
        }
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(k, v)
