"""Paged vs contiguous KV serving under a fixed cache-token budget.

Both engines get the SAME number of KV cache tokens. The contiguous
engine must carve them into ``max_len``-sized slot regions, so a
heavy-tailed chat trace (short requests + one straggler, shared system
prompt) OOM-queues: most admitted requests use a fraction of their
region while the queue waits for whole slots. The paged engine
(``repro.serve.PagedServeEngine``) spends the identical budget as a
block pool — admission claims only the blocks a prompt actually needs,
decode grows one block at a time, finished requests free
block-granularly, and the shared system prompt is stored once
(refcounted prefix blocks) — so it sustains strictly more concurrently
admitted requests per block pool, with greedy outputs bit-identical to
per-request lockstep runs.

  PYTHONPATH=src:. python benchmarks/serve_paged.py [--arch yi-6b]

Writes ``BENCH_serve_paged.json`` through the shared record schema
(``benchmarks.run.write_record`` — the same file ``benchmarks/run.py
--record/--check serve_paged`` reads) and exits non-zero if the paged
engine does not beat contiguous admission or any output diverges. With
>= 8 devices the trace is also replayed on disaggregated prefill/decode
mesh slices (``repro.launch.mesh.make_disaggregated_meshes``) and
checked bit-identical again. The paged engine runs under a
``repro.obs.ServeTelemetry``; its metrics snapshot rides along in the
result (``"metrics"``).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

if "jax" not in sys.modules and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # standalone runs get 8 fake devices so the disaggregated section can
    # exercise two (1, 2, 2) mesh slices on CPU (tests/conftest.py does
    # the same for pytest); a no-op when jax is already up
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import numpy as np

from repro import configs, obs
from repro.models import transformer as T
from repro.serve import (
    ContinuousServeEngine,
    PagedServeEngine,
    Request,
    ServeEngine,
)

SYS_LEN = 8        # shared system prompt: 2 prefix blocks at block_size 4


def build_trace(n_requests: int, vocab: int, seed: int = 0) -> list[Request]:
    """Heavy-tailed chat mix over one system prompt: every prompt starts
    with the same SYS_LEN tokens (prefix-sharable), outputs are mostly
    short with one ~5x straggler — so contiguous max_len slot regions are
    almost entirely padding."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, SYS_LEN).astype(np.int32)
    reqs = []
    for uid in range(n_requests):
        extra = int(rng.integers(1, 9))
        steps = int(rng.integers(24, 33)) if uid == 0 else \
            int(rng.integers(4, 9))
        prompt = np.concatenate(
            [sys_prompt, rng.integers(0, vocab, extra).astype(np.int32)])
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=steps))
    return reqs


def _drive(eng, reqs) -> dict:
    """Step an engine to drain, recording concurrently-admitted requests
    per iteration (the admission curve the benchmark compares)."""
    for r in reqs:
        eng.submit(r)
    admitted, outs = [], []
    t0 = time.monotonic()
    while eng.has_work:
        outs.extend(eng.step())
        admitted.append(len(eng.active_uids))
    dt = time.monotonic() - t0
    curve = [a for a in admitted if a > 0] or [0]
    return {"outputs": {o.uid: o.tokens for o in outs},
            "peak_admitted": max(curve),
            "mean_admitted": float(np.mean(curve)),
            "iterations": len(admitted), "wall_s": dt}


def run(arch: str = "yi-6b", n_requests: int = 10, block_size: int = 4,
        seed: int = 0, disaggregated: bool | None = None) -> dict:
    cfg = configs.get(arch).reduced()
    params, _ = T.init_params(cfg, jax.random.key(0))
    reqs = build_trace(n_requests, cfg.vocab_size, seed)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    max_len = -(-max_len // block_size) * block_size   # round to blocks

    # ---- one fixed budget of KV cache tokens for BOTH engines
    budget_tokens = 2 * max_len
    cont_slots = budget_tokens // max_len              # = 2 whole regions
    n_blocks = budget_tokens // block_size
    paged_slots = min(n_requests, 3 * cont_slots)

    cont = ContinuousServeEngine(cfg, params, n_slots=cont_slots,
                                 max_len=max_len, prefill_chunk=block_size)
    c = _drive(cont, reqs)

    tel = obs.ServeTelemetry(engine="paged")
    paged = PagedServeEngine(cfg, params, n_slots=paged_slots,
                             max_len=max_len, prefill_chunk=block_size,
                             block_size=block_size, n_blocks=n_blocks,
                             telemetry=tel)
    p = _drive(paged, reqs)
    tel.record_stats(paged.stats)

    refs = ServeEngine(cfg, params, max_len=max_len)
    mismatches = []
    for r in reqs:
        ref = refs.generate(r.prompt[None, :], steps=r.max_new_tokens)
        for tag, d in (("contiguous", c), ("paged", p)):
            if not np.array_equal(d["outputs"][r.uid], ref.tokens[0]):
                mismatches.append(f"{tag}:{r.uid}")

    out = {
        "arch": cfg.name, "requests": n_requests,
        "budget_tokens": budget_tokens, "block_size": block_size,
        "n_blocks": n_blocks, "max_len": max_len,
        "contiguous_slots": cont_slots, "paged_slots": paged_slots,
        "contiguous_peak_admitted": c["peak_admitted"],
        "contiguous_mean_admitted": round(c["mean_admitted"], 3),
        "paged_peak_admitted": p["peak_admitted"],
        "paged_mean_admitted": round(p["mean_admitted"], 3),
        "admission_ratio": round(p["mean_admitted"]
                                 / max(c["mean_admitted"], 1e-9), 3),
        "contiguous_iterations": c["iterations"],
        "paged_iterations": p["iterations"],
        "contiguous_s": round(c["wall_s"], 3),
        "paged_s": round(p["wall_s"], 3),
        "paged_peak_blocks_in_use": paged.stats.peak_blocks_in_use,
        "paged_prefix_block_hits": paged.stats.prefix_block_hits,
        "paged_evictions": paged.stats.evictions,
        "paged_admission_waits": paged.stats.admission_waits,
        "bit_identical": not mismatches,
        "mismatched": mismatches,
        "paged_sustains_more": (
            p["peak_admitted"] > c["peak_admitted"]
            and p["mean_admitted"] > c["mean_admitted"]),
        "stats": paged.stats.snapshot(),
        "metrics": obs.snapshot(tel.registry),
    }

    # ---- disaggregated prefill/decode slices (optional; needs 8 devices)
    if disaggregated is None:
        disaggregated = jax.device_count() >= 8
    if disaggregated:
        from repro.launch.mesh import make_disaggregated_meshes
        pm, dm = make_disaggregated_meshes()
        deng = PagedServeEngine(cfg, params, n_slots=paged_slots,
                                max_len=max_len, prefill_chunk=block_size,
                                block_size=block_size, n_blocks=n_blocks,
                                prefill_mesh=pm, decode_mesh=dm)
        d = _drive(deng, reqs)
        out["disaggregated_bit_identical"] = all(
            np.array_equal(d["outputs"][u], p["outputs"][u])
            for u in d["outputs"])
        out["disaggregated_s"] = round(d["wall_s"], 3)
        out["disaggregated_devices"] = [len(pm.devices.flat),
                                        len(dm.devices.flat)]
    return out


def main() -> None:
    from benchmarks.run import write_record

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--block-size", type=int, default=4)
    ap.add_argument("--out", default=None,
                    help="record path (default BENCH_serve_paged.json at "
                         "the repo root, the --check baseline)")
    args = ap.parse_args()
    kwargs = dict(arch=args.arch, n_requests=args.requests,
                  block_size=args.block_size)
    out = run(**kwargs)
    print(f"{out['arch']}: {out['requests']} requests, "
          f"{out['budget_tokens']}-token KV budget "
          f"({out['n_blocks']} blocks of {out['block_size']} / "
          f"{out['contiguous_slots']} contiguous regions)")
    print(f"  contiguous admitted peak {out['contiguous_peak_admitted']} "
          f"mean {out['contiguous_mean_admitted']} "
          f"({out['contiguous_iterations']} iters, {out['contiguous_s']}s)")
    print(f"  paged      admitted peak {out['paged_peak_admitted']} "
          f"mean {out['paged_mean_admitted']} "
          f"({out['paged_iterations']} iters, {out['paged_s']}s; "
          f"{out['paged_prefix_block_hits']} prefix hits, "
          f"{out['paged_evictions']} evictions, peak "
          f"{out['paged_peak_blocks_in_use']}/{out['n_blocks']} blocks)")
    print(f"  admission ratio {out['admission_ratio']}x, bit-identical "
          f"{out['bit_identical']}")
    if "disaggregated_bit_identical" in out:
        print(f"  disaggregated prefill/decode "
              f"{out['disaggregated_devices']} devices: bit-identical "
              f"{out['disaggregated_bit_identical']} "
              f"({out['disaggregated_s']}s)")
    path = write_record("serve_paged", out, kwargs, path=args.out)
    print(f"wrote {path}")
    if not out["bit_identical"]:
        raise SystemExit(f"outputs diverged: {out['mismatched']}")
    if not out["paged_sustains_more"]:
        raise SystemExit("paged engine did not sustain more admitted "
                         "requests than contiguous on the same budget")
    if not out.get("disaggregated_bit_identical", True):
        raise SystemExit("disaggregated replay diverged")


if __name__ == "__main__":
    main()
